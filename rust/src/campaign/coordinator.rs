//! `campaign serve`: the coordinator side of the wire-backed work
//! plane (DESIGN.md §15).
//!
//! The coordinator owns everything a distributed sweep must agree on:
//! the resolved [`GridPlan`] (cell identity = grid index), the
//! checkpoint journal, the per-cell trial-event buffers, and the
//! merged eval-cache / transcript journals. Workers (`campaign work`)
//! own everything that is per-process: the evaluator stack, the
//! provider, and the engine threads.
//!
//! Protocol (hand-rolled HTTP/1.1 + JSON over
//! [`crate::util::httpwire`]):
//!
//! | endpoint         | body → reply                                       |
//! |------------------|----------------------------------------------------|
//! | `GET /config`    | → sweep knobs the worker must mirror               |
//! | `POST /claim`    | `{worker}` → next cell / `idle` / `done` / `failed`|
//! | `POST /events`   | `{idx, epoch, events:[…]}` → buffered (epoch-gated)|
//! | `POST /upload`   | `{kind, lines:[…]}` → dedup-merged into the stores |
//! | `POST /complete` | `{idx, epoch, record}` → checkpointed, cell done   |
//! | `POST /release`  | `{idx, epoch}` → cell re-offered at epoch+1        |
//! | `POST /fail`     | `{idx, epoch, error}` → sweep aborts               |
//! | `GET /warm`      | → merged transcript-journal lines (resume warm-up) |
//! | `GET /bank`      | → warm-start bank snapshot lines (DESIGN.md §18)   |
//! | `GET /status`    | → live [`PlaneStats`] counters                     |
//!
//! **Determinism contract.** Cells are offered in grid order; every
//! completed cell's record is deterministic per (method, model, op,
//! seed) (the AI CUDA Engineer's cross-op archive excepted, exactly as
//! for in-process sweeps). Event uploads are buffered per cell and the
//! finalized journal is rewritten in grid order at shutdown, so a
//! coordinator + N workers sweep produces the same `records.jsonl` and
//! `events.jsonl` bytes as an uninterrupted `--concurrency 1` run —
//! including across a worker death: the interrupted cell's buffer
//! keeps the partial stream, the next claimant resumes at trial
//! granularity (replayed trials suppressed, verified by `src_hash`),
//! and the buffer ends up holding exactly the uninterrupted stream.
//!
//! **Epochs.** Each re-offer bumps the cell's epoch; event batches and
//! completions carrying a stale epoch are rejected (409), never
//! merged — accepting them would interleave a presumed-dead claimant's
//! duplicate events into the new claimant's continuation. A worker
//! that dies without releasing (SIGKILL, not the simulated trial-gate
//! kill) leaves its cell claimed; restart the sweep with `--resume` to
//! finish it, exactly as for an in-process kill.

use std::path::Path;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::metrics::{EventStats, PlaneStats};
use crate::methods::KernelRunRecord;
use crate::store::events::{self, EventJournal, TrialEvent};
use crate::store::{EvalStore, TranscriptStore};
use crate::tasks::TaskRegistry;
use crate::util::httpwire::{Request, Response, Server};
use crate::util::json::{self, Json};
use crate::{eyre, Result};

use super::plane::lock_tolerant;
use super::{cell_of, job_key, plan_grid, results, CampaignConfig, GridPlan, Job};

/// One grid cell as the coordinator tracks it.
struct CellState {
    job: Job,
    /// Claim generation; bumped on every re-offer.
    epoch: u64,
    status: CellStatus,
    /// Buffered journal events: the prior leg's partial stream on
    /// resume, plus every batch uploaded by current-epoch claimants.
    /// Replayed to the finalized journal in grid order at shutdown.
    events: Vec<TrialEvent>,
    /// `Some(pairs)` when a partial prior run exists: the next
    /// claimant resumes, replaying these (trial, src_hash) pairs warm.
    verify: Option<Vec<(usize, String)>>,
    record: Option<KernelRunRecord>,
}

enum CellStatus {
    Available,
    Claimed,
    Done,
}

struct Inner {
    cells: Vec<CellState>,
    done: usize,
    failed: Option<String>,
    stats: PlaneStats,
    appender: Option<results::Appender>,
    evals: Option<Arc<EvalStore>>,
    transcripts: Option<Arc<TranscriptStore>>,
}

struct State {
    inner: Mutex<Inner>,
    cvar: Condvar,
    // Sweep knobs the workers mirror (GET /config).
    budget: usize,
    repair: String,
    provider: String,
    prefetch: usize,
    goal: String,
    /// Warm-start bank journal lines (canonical serialization), read
    /// once at startup and shipped verbatim to every worker over
    /// `GET /bank` — all claimants warm-start from the identical
    /// snapshot, exactly as an in-process sweep would (DESIGN.md §18).
    warm_lines: Vec<String>,
    /// Serve start time, for the `/metrics` uptime/throughput gauges
    /// (observability only — never feeds determinism-bearing state).
    started: Instant,
}

/// A running `campaign serve` daemon. [`Coordinator::wait`] blocks
/// until the grid drains (or a worker reports a fatal error), then
/// finalizes the journals and returns the merged records.
pub struct Coordinator {
    server: Server,
    state: Arc<State>,
    events_path: Option<std::path::PathBuf>,
}

impl Coordinator {
    /// Resolve the grid and start serving it on `bind`
    /// (`host:port`, e.g. `127.0.0.1:7717`).
    ///
    /// `cache` is the merged eval-cache journal workers' uploads land
    /// in (independent of any cache the workers use locally).
    pub fn start(
        cfg: &CampaignConfig,
        registry: &TaskRegistry,
        bind: &str,
        cache: Option<&Path>,
    ) -> Result<Self> {
        let GridPlan { jobs, prior, .. } = plan_grid(cfg, registry)?;

        let mut cells: Vec<CellState> = jobs
            .into_iter()
            .map(|job| CellState {
                job,
                epoch: 0,
                status: CellStatus::Available,
                events: Vec::new(),
                verify: None,
                record: None,
            })
            .collect();

        // Resume: prior records pre-fill their cells (Done from the
        // start, nothing re-appended to the checkpoint), and the prior
        // event journal seeds the buffers — full streams for finished
        // cells, partial stream + warm verify list for interrupted
        // ones. The finalized journal rewrite then preserves prior
        // cells' events in grid order.
        let mut resumed = 0usize;
        if !prior.is_empty() {
            for r in &prior {
                let key = cell_of(r);
                if let Some(cell) = cells.iter_mut().find(|c| job_key(&c.job) == key) {
                    cell.status = CellStatus::Done;
                    cell.record = Some(r.clone());
                    resumed += 1;
                }
            }
        }
        if let Some(path) = &cfg.events {
            if cfg.resume && path.exists() {
                let loaded = EventJournal::load(path)?;
                let mut partial = events::completed_trials(&loaded);
                for cell in cells.iter_mut() {
                    let key = job_key(&cell.job);
                    cell.events = loaded.iter().filter(|e| e.cell() == key).cloned().collect();
                    cell.verify = partial.remove(&key);
                }
            }
        }

        // Same checkpoint lifecycle as the in-process plane: resumed
        // sweeps append, fresh sweeps start the journal over.
        let appender = match &cfg.checkpoint {
            Some(path) if cfg.resume => Some(results::Appender::open(path)?),
            Some(path) => Some(results::Appender::create(path)?),
            None => None,
        };
        let transcripts = match &cfg.provider {
            crate::llm::ProviderSpec::Replay(_) => None, // replay records nothing
            _ => match &cfg.transcripts {
                Some(path) => Some(TranscriptStore::open(path)?),
                None => None,
            },
        };
        let evals = match cache {
            Some(path) => Some(EvalStore::open(path)?),
            None => None,
        };
        // Read-only snapshot; export_lines() re-serializes canonically
        // (torn tails repaired, duplicates collapsed) so the wire ships
        // exactly the entry set a local `--warm-start` run would load.
        let warm_lines = match &cfg.warm_start {
            Some(path) => crate::bank::KernelBank::load(path)?.export_lines(),
            None => Vec::new(),
        };

        let done = cells.iter().filter(|c| matches!(c.status, CellStatus::Done)).count();
        let stats = PlaneStats { grid: cells.len(), resumed, ..PlaneStats::default() };
        let state = Arc::new(State {
            inner: Mutex::new(Inner {
                cells,
                done,
                failed: None,
                stats,
                appender,
                evals,
                transcripts,
            }),
            cvar: Condvar::new(),
            budget: cfg.budget,
            repair: cfg.repair.label(),
            provider: cfg.provider.label(),
            prefetch: cfg.prefetch,
            goal: cfg.goal.label(),
            warm_lines,
            started: Instant::now(),
        });

        let handler = {
            let state = state.clone();
            Arc::new(move |req: &Request| handle(&state, req))
        };
        let server = Server::bind(bind, handler)?;
        Ok(Self { server, state, events_path: cfg.events.clone() })
    }

    /// The coordinator's base URL (`http://host:port`).
    pub fn url(&self) -> String {
        self.server.url()
    }

    /// Block until the grid drains or a worker reports a fatal error,
    /// then shut the server down, finalize the journals, and return
    /// the merged, sorted records plus the plane counters.
    pub fn wait(mut self) -> Result<(Vec<KernelRunRecord>, PlaneStats)> {
        {
            let mut g = lock_tolerant(&self.state.inner);
            while g.failed.is_none() && g.done < g.cells.len() {
                g = self
                    .state
                    .cvar
                    .wait(g)
                    .unwrap_or_else(|poisoned| poisoned.into_inner());
            }
        }
        // Stop accepting connections before touching the journals;
        // stragglers see a connection error and treat the plane as
        // drained.
        self.server.shutdown();

        let mut g = lock_tolerant(&self.state.inner);
        if let Some(msg) = g.failed.take() {
            return Err(eyre!("{msg}"));
        }

        // Finalized event journal: every cell's buffered stream, in
        // grid order — byte-identical to an uninterrupted
        // `--concurrency 1` sweep's journal.
        if let Some(path) = &self.events_path {
            let journal = EventJournal::create(path)?;
            for cell in &g.cells {
                for ev in &cell.events {
                    journal.append(ev)?;
                }
            }
            journal.flush()?;
        }
        if let Some(store) = &g.evals {
            store.flush()?;
        }
        if let Some(store) = &g.transcripts {
            store.flush()?;
        }

        let mut records: Vec<KernelRunRecord> =
            g.cells.iter_mut().filter_map(|c| c.record.take()).collect();
        records.sort_by(|a, b| {
            (&a.method, &a.model, &a.op, a.seed).cmp(&(&b.method, &b.model, &b.op, b.seed))
        });
        let stats = g.stats.clone();
        Ok((records, stats))
    }
}

/// Run a coordinator to completion: start, announce, wait.
pub fn serve(
    cfg: &CampaignConfig,
    registry: &TaskRegistry,
    bind: &str,
    cache: Option<&Path>,
) -> Result<(Vec<KernelRunRecord>, PlaneStats)> {
    let coord = Coordinator::start(cfg, registry, bind, cache)?;
    if !cfg.quiet {
        let (grid, resumed) = {
            let g = lock_tolerant(&coord.state.inner);
            (g.stats.grid, g.stats.resumed)
        };
        eprintln!(
            "campaign coordinator: serving {grid} cells on {}{} \
             (budget {}, repair {}, provider {}, goal {})",
            coord.url(),
            if resumed > 0 {
                format!(", {resumed} resumed from checkpoint")
            } else {
                String::new()
            },
            coord.state.budget,
            coord.state.repair,
            coord.state.provider,
            coord.state.goal,
        );
    }
    coord.wait()
}

// ---------------------------------------------------------------------
// Protocol handlers

fn err_json(msg: impl Into<String>) -> Json {
    Json::obj(vec![("error", Json::Str(msg.into()))])
}

fn ok_json() -> Json {
    Json::obj(vec![("ok", Json::Bool(true))])
}

fn handle(state: &State, req: &Request) -> Response {
    let (code, body) = match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/config") => (
            200,
            Json::obj(vec![
                ("budget", Json::Num(state.budget as f64)),
                ("repair", Json::Str(state.repair.clone())),
                ("provider", Json::Str(state.provider.clone())),
                ("prefetch", Json::Num(state.prefetch as f64)),
                ("goal", Json::Str(state.goal.clone())),
                // Absent on pre-bank coordinators; workers treat a
                // missing key as a cold start.
                ("warm_start", Json::Bool(!state.warm_lines.is_empty())),
            ]),
        ),
        ("POST", "/claim") => claim(state),
        ("POST", "/events") => with_body(state, req, ingest_events),
        ("POST", "/upload") => with_body(state, req, ingest_upload),
        ("POST", "/complete") => with_body(state, req, complete),
        ("POST", "/release") => with_body(state, req, release),
        ("POST", "/fail") => with_body(state, req, fail),
        ("GET", "/warm") => warm(state),
        ("GET", "/bank") => bank(state),
        ("GET", "/status") => status(state),
        // The one non-JSON endpoint: Prometheus-style text scrape.
        ("GET", "/metrics") => return metrics_text(state),
        _ => (404, err_json(format!("no such endpoint: {} {}", req.method, req.path))),
    };
    Response::json(code, body)
}

/// `GET /metrics`: the live sweep state in Prometheus text exposition
/// format, folded from the same per-cell event buffers the finalized
/// journal is rewritten from. Purely observational — scraping never
/// touches determinism-bearing state (wall-clock appears only in the
/// uptime/throughput gauges, which exist for dashboards, not records).
fn metrics_text(state: &State) -> Response {
    let g = lock_tolerant(&state.inner);
    let s = &g.stats;
    let mut ev = EventStats::default();
    for cell in &g.cells {
        for e in &cell.events {
            ev.fold(e);
        }
    }
    // Per-goal completions: runs and valid runs keyed by the record's
    // goal label (one key on single-goal sweeps; stable BTreeMap order).
    let mut goals: std::collections::BTreeMap<&str, (u64, u64)> =
        std::collections::BTreeMap::new();
    for cell in &g.cells {
        if let Some(r) = &cell.record {
            let slot = goals.entry(r.goal.as_str()).or_insert((0, 0));
            slot.0 += 1;
            if r.any_valid {
                slot.1 += 1;
            }
        }
    }
    let uptime = state.started.elapsed().as_secs_f64();
    let trials = ev.groups as f64;
    let mut out = String::with_capacity(2048);
    let mut gauge = |name: &str, help: &str, v: f64| {
        out.push_str(&format!(
            "# HELP {name} {help}\n# TYPE {name} gauge\n{name} {v}\n"
        ));
    };
    gauge("campaign_uptime_seconds", "Seconds since the coordinator started.", uptime);
    gauge(
        "campaign_trials_per_second",
        "Completed trial groups per second of uptime.",
        if uptime > 0.0 { trials / uptime } else { 0.0 },
    );
    gauge("campaign_grid_cells", "Total cells in the sweep grid.", s.grid as f64);
    gauge("campaign_cells_resumed", "Cells pre-filled from a checkpoint.", s.resumed as f64);
    gauge("campaign_cells_done", "Cells with a completed record.", g.done as f64);
    gauge("campaign_claims_total", "Cell claims issued.", s.claims as f64);
    gauge("campaign_reclaims_total", "Cells re-offered after a release.", s.reclaims as f64);
    gauge("campaign_completions_total", "Records accepted.", s.completions as f64);
    gauge(
        "campaign_duplicate_completions_total",
        "Stale or duplicate completions rejected.",
        s.duplicate_completions as f64,
    );
    gauge("campaign_event_batches_total", "Event batches accepted.", s.event_batches as f64);
    gauge(
        "campaign_stale_event_batches_total",
        "Event batches rejected for a stale epoch.",
        s.stale_event_batches as f64,
    );
    gauge("campaign_events_total", "Trial events buffered.", s.events as f64);
    gauge(
        "campaign_eval_cache_lines_merged_total",
        "Eval-cache lines dedup-merged from worker uploads.",
        s.eval_lines_merged as f64,
    );
    gauge(
        "campaign_transcript_lines_merged_total",
        "Transcript lines dedup-merged from worker uploads.",
        s.transcript_lines_merged as f64,
    );
    gauge("evo_runs_started_total", "RunStarted events seen.", ev.runs_started as f64);
    gauge("evo_runs_finished_total", "RunFinished events seen.", ev.runs_finished as f64);
    gauge("evo_trial_groups_total", "Trial groups completed.", trials);
    gauge(
        "evo_guard_rejected_total",
        "Candidates rejected by the stage-0 guard.",
        ev.guard_failed as f64,
    );
    gauge("evo_repair_attempts_total", "Repair attempts made.", ev.repair_attempts as f64);
    gauge("evo_repairs_mended_total", "Repairs that mended a candidate.", ev.repairs_mended as f64);
    gauge("evo_new_bests_total", "New-best promotions.", ev.new_bests as f64);
    gauge("evo_prompt_tokens_total", "Prompt tokens spent.", ev.prompt_tokens as f64);
    gauge(
        "evo_completion_tokens_total",
        "Completion tokens spent.",
        ev.completion_tokens as f64,
    );
    // Labeled families: trial outcomes by evaluation stage verdict,
    // and per-goal completion/validity counters.
    out.push_str(
        "# HELP evo_trials_total Trials by evaluation outcome.\n\
         # TYPE evo_trials_total gauge\n",
    );
    for (outcome, n) in &ev.outcomes {
        out.push_str(&format!("evo_trials_total{{outcome=\"{outcome}\"}} {n}\n"));
    }
    out.push_str(
        "# HELP campaign_goal_runs_total Completed records by --goal label.\n\
         # TYPE campaign_goal_runs_total gauge\n",
    );
    for (goal, (runs, _)) in &goals {
        out.push_str(&format!("campaign_goal_runs_total{{goal=\"{goal}\"}} {runs}\n"));
    }
    out.push_str(
        "# HELP campaign_goal_valid_runs_total Records with a valid improvement, by --goal label.\n\
         # TYPE campaign_goal_valid_runs_total gauge\n",
    );
    for (goal, (_, valid)) in &goals {
        out.push_str(&format!(
            "campaign_goal_valid_runs_total{{goal=\"{goal}\"}} {valid}\n"
        ));
    }
    Response::text(200, out)
}

fn with_body(
    state: &State,
    req: &Request,
    f: fn(&State, &Json) -> (u16, Json),
) -> (u16, Json) {
    match json::parse(&req.body) {
        Ok(v) => f(state, &v),
        Err(e) => (400, err_json(format!("bad request body: {e}"))),
    }
}

fn get_usize(v: &Json, key: &str) -> Result<usize> {
    v.get(key)
        .and_then(|x| x.as_usize())
        .ok_or_else(|| eyre!("missing numeric field `{key}`"))
}

fn get_u64(v: &Json, key: &str) -> Result<u64> {
    v.get(key)
        .and_then(|x| x.as_u64())
        .ok_or_else(|| eyre!("missing numeric field `{key}`"))
}

/// Look up the addressed cell and check its epoch. Borrow-splitting
/// helper: returns the index, callers re-borrow.
fn check_cell(inner: &Inner, v: &Json) -> std::result::Result<usize, (u16, Json)> {
    let idx = get_usize(v, "idx").map_err(|e| (400, err_json(e.to_string())))?;
    let epoch = get_u64(v, "epoch").map_err(|e| (400, err_json(e.to_string())))?;
    let cell = inner
        .cells
        .get(idx)
        .ok_or_else(|| (400, err_json(format!("cell index {idx} out of range"))))?;
    if cell.epoch != epoch {
        return Err((
            409,
            err_json(format!(
                "stale epoch {epoch} for cell {idx} (current {})",
                cell.epoch
            )),
        ));
    }
    Ok(idx)
}

fn claim(state: &State) -> (u16, Json) {
    let mut g = lock_tolerant(&state.inner);
    if let Some(msg) = &g.failed {
        return (
            200,
            Json::obj(vec![
                ("status", Json::Str("failed".into())),
                ("error", Json::Str(msg.clone())),
            ]),
        );
    }
    let next = g
        .cells
        .iter()
        .position(|c| matches!(c.status, CellStatus::Available));
    match next {
        Some(idx) => {
            g.cells[idx].status = CellStatus::Claimed;
            g.stats.claims += 1;
            let cell = &g.cells[idx];
            let verify: Vec<Json> = cell
                .verify
                .iter()
                .flatten()
                .map(|(t, h)| {
                    Json::Arr(vec![Json::Num(*t as f64), Json::Str(h.clone())])
                })
                .collect();
            (
                200,
                Json::obj(vec![
                    ("status", Json::Str("cell".into())),
                    ("idx", Json::Num(idx as f64)),
                    ("epoch", Json::Num(cell.epoch as f64)),
                    ("method", Json::Str(cell.job.method.name())),
                    ("model", Json::Str(cell.job.model.name.to_string())),
                    ("op", Json::Str(cell.job.op.name.clone())),
                    // Decimal string: u64 seeds must not round-trip
                    // through f64.
                    ("seed", Json::Str(cell.job.seed.to_string())),
                    ("resumed", Json::Bool(cell.verify.is_some())),
                    ("verify", Json::Arr(verify)),
                ]),
            )
        }
        None if g.done == g.cells.len() => {
            (200, Json::obj(vec![("status", Json::Str("done".into()))]))
        }
        // Cells are in flight on other claimants: poll again shortly.
        None => (200, Json::obj(vec![("status", Json::Str("idle".into()))])),
    }
}

fn ingest_events(state: &State, v: &Json) -> (u16, Json) {
    let mut g = lock_tolerant(&state.inner);
    let idx = match check_cell(&g, v) {
        Ok(idx) => idx,
        Err(reject) => {
            g.stats.stale_event_batches += 1;
            return reject;
        }
    };
    if g.cells[idx].record.is_some() {
        g.stats.stale_event_batches += 1;
        return (409, err_json(format!("cell {idx} is already complete")));
    }
    let Some(items) = v.get("events").and_then(|e| e.as_arr()) else {
        return (400, err_json("missing `events` array"));
    };
    let mut parsed = Vec::with_capacity(items.len());
    for item in items {
        match events::event_from_json(item) {
            Ok(ev) => parsed.push(ev),
            Err(e) => return (400, err_json(format!("bad event: {e:#}"))),
        }
    }
    g.stats.event_batches += 1;
    g.stats.events += parsed.len() as u64;
    g.cells[idx].events.extend(parsed);
    (200, ok_json())
}

fn ingest_upload(state: &State, v: &Json) -> (u16, Json) {
    let Some(kind) = v.get("kind").and_then(|k| k.as_str()) else {
        return (400, err_json("missing `kind`"));
    };
    let Some(lines) = v.get("lines").and_then(|l| l.as_arr()) else {
        return (400, err_json("missing `lines` array"));
    };
    let mut g = lock_tolerant(&state.inner);
    let mut merged = 0u64;
    for line in lines {
        let Some(text) = line.as_str() else {
            return (400, err_json("`lines` must hold strings"));
        };
        let result = match kind {
            "eval" => g.evals.as_ref().map(|s| s.ingest_line(text)),
            "transcript" => g.transcripts.as_ref().map(|s| s.ingest_line(text)),
            other => return (400, err_json(format!("unknown upload kind `{other}`"))),
        };
        match result {
            Some(Ok(true)) => merged += 1,
            Some(Ok(false)) | None => {} // duplicate, or no store configured
            Some(Err(e)) => return (500, err_json(format!("ingest failed: {e:#}"))),
        }
    }
    match kind {
        "eval" => g.stats.eval_lines_merged += merged,
        _ => g.stats.transcript_lines_merged += merged,
    }
    (200, Json::obj(vec![("merged", Json::Num(merged as f64))]))
}

fn complete(state: &State, v: &Json) -> (u16, Json) {
    let mut g = lock_tolerant(&state.inner);
    let idx = match check_cell(&g, v) {
        Ok(idx) => idx,
        Err(reject) => {
            g.stats.duplicate_completions += 1;
            return reject;
        }
    };
    if matches!(g.cells[idx].status, CellStatus::Done) {
        g.stats.duplicate_completions += 1;
        return (409, err_json(format!("cell {idx} is already complete")));
    }
    let record = match v.get("record").ok_or_else(|| eyre!("missing `record`")) {
        Ok(r) => match KernelRunRecord::from_json(r) {
            Ok(rec) => rec,
            Err(e) => return (400, err_json(format!("bad record: {e:#}"))),
        },
        Err(e) => return (400, err_json(e.to_string())),
    };
    if let Some(appender) = &mut g.appender {
        if let Err(e) = appender.append(&record) {
            eprintln!("warning: checkpoint append failed: {e:#}");
        }
    }
    g.cells[idx].record = Some(record);
    g.cells[idx].status = CellStatus::Done;
    g.done += 1;
    g.stats.completions += 1;
    state.cvar.notify_all();
    (200, ok_json())
}

fn release(state: &State, v: &Json) -> (u16, Json) {
    let mut g = lock_tolerant(&state.inner);
    let idx = match check_cell(&g, v) {
        Ok(idx) => idx,
        Err(reject) => return reject,
    };
    if !matches!(g.cells[idx].status, CellStatus::Claimed) {
        return (409, err_json(format!("cell {idx} is not claimed")));
    }
    // Re-offer at the next epoch with a warm verify list folded from
    // the buffered partial stream — the next claimant resumes exactly
    // as a single-process `--resume` leg would.
    let key = job_key(&g.cells[idx].job);
    let fold = events::completed_trials(&g.cells[idx].events);
    let cell = &mut g.cells[idx];
    cell.verify = match fold.into_iter().find(|(k, _)| *k == key) {
        Some((_, pairs)) => Some(pairs),
        // Never started: offer fresh.
        None if cell.events.is_empty() => None,
        // The stream reached RunFinished but the record never arrived
        // (claimant died in the gap): drop the buffer and redo the
        // cell from scratch so the journal holds the stream exactly
        // once.
        None => {
            cell.events.clear();
            None
        }
    };
    cell.epoch += 1;
    cell.status = CellStatus::Available;
    g.stats.reclaims += 1;
    state.cvar.notify_all();
    (200, ok_json())
}

fn fail(state: &State, v: &Json) -> (u16, Json) {
    let msg = v
        .get("error")
        .and_then(|e| e.as_str())
        .unwrap_or("worker reported an unspecified error")
        .to_string();
    let mut g = lock_tolerant(&state.inner);
    if g.failed.is_none() {
        g.failed = Some(msg);
    }
    state.cvar.notify_all();
    (200, ok_json())
}

/// Ship the merged transcript journal so a re-claiming worker can
/// seed its local journal and replay a dead claimant's completed
/// trials from recorded provider calls instead of re-generating live.
fn warm(state: &State) -> (u16, Json) {
    let g = lock_tolerant(&state.inner);
    let lines: Vec<Json> = match &g.transcripts {
        Some(store) => {
            if let Err(e) = store.flush() {
                return (500, err_json(format!("transcript flush failed: {e:#}")));
            }
            match std::fs::read_to_string(store.path()) {
                Ok(text) => text
                    .lines()
                    .filter(|l| !l.trim().is_empty())
                    .map(|l| Json::Str(l.to_string()))
                    .collect(),
                Err(_) => Vec::new(), // journal not created yet
            }
        }
        None => Vec::new(),
    };
    (200, Json::obj(vec![("lines", Json::Arr(lines))]))
}

/// Ship the warm-start bank snapshot (DESIGN.md §18): the canonical
/// journal lines read at startup. Workers rebuild the identical
/// read-only [`crate::bank::KernelBank`] from them, so warm-started
/// `campaign work` runs match local `--warm-start` runs byte-for-byte.
fn bank(state: &State) -> (u16, Json) {
    let lines: Vec<Json> =
        state.warm_lines.iter().map(|l| Json::Str(l.clone())).collect();
    (200, Json::obj(vec![("lines", Json::Arr(lines))]))
}

fn status(state: &State) -> (u16, Json) {
    let g = lock_tolerant(&state.inner);
    let s = &g.stats;
    (
        200,
        Json::obj(vec![
            ("grid", Json::Num(s.grid as f64)),
            ("resumed", Json::Num(s.resumed as f64)),
            ("done", Json::Num(g.done as f64)),
            ("claims", Json::Num(s.claims as f64)),
            ("reclaims", Json::Num(s.reclaims as f64)),
            ("completions", Json::Num(s.completions as f64)),
            ("duplicate_completions", Json::Num(s.duplicate_completions as f64)),
            ("event_batches", Json::Num(s.event_batches as f64)),
            ("stale_event_batches", Json::Num(s.stale_event_batches as f64)),
            ("events", Json::Num(s.events as f64)),
            ("eval_lines_merged", Json::Num(s.eval_lines_merged as f64)),
            ("transcript_lines_merged", Json::Num(s.transcript_lines_merged as f64)),
            ("failed", Json::Bool(g.failed.is_some())),
        ]),
    )
}
