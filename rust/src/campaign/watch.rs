//! `campaign watch`: a live dashboard over a running sweep.
//!
//! Two targets, one renderer:
//!
//! * **Journal mode** — `campaign watch events.jsonl` tails the
//!   structured trial-event journal (`--events`) by byte offset,
//!   folding new complete lines into the same
//!   [`metrics::EventStats`] aggregate `report events` uses plus a
//!   per-cell progress map (trials done vs budget, best speedup,
//!   stage-aware validity split, ETA from the observed trial
//!   throughput). Works on any sweep with `--events`, local or
//!   distributed, including one on another machine via a shared
//!   filesystem.
//! * **Coordinator mode** — `campaign watch http://host:port` polls a
//!   `campaign serve` daemon's `GET /status` counters and renders the
//!   plane view (cells done / claimed / re-offered, merged journal
//!   lines, ETA from the completion rate).
//!
//! Watching is strictly observational: the journal is opened
//! read-only, the coordinator endpoint is a pure read, and nothing
//! here feeds determinism-bearing state — wall-clock time appears only
//! in the throughput/ETA lines. `--once` renders a single snapshot and
//! exits (the scriptable/CI form); otherwise the dashboard refreshes
//! every `--interval` seconds until interrupted (journal mode keeps
//! tailing like `tail -f`; coordinator mode exits on its own when the
//! sweep drains or the coordinator goes away).

use std::collections::BTreeMap;
use std::path::Path;
use std::time::{Duration, Instant};

use crate::metrics::EventStats;
use crate::store::events::{self, CellKey, TrialEventKind};
use crate::util::httpwire::{request_json, split_url};
use crate::util::json::{self, Json};
use crate::{eyre, Result};

/// How `campaign watch` is parameterized.
#[derive(Debug, Clone)]
pub struct WatchOpts {
    /// Refresh period between snapshots.
    pub interval: Duration,
    /// Render one snapshot and exit (CI / scripting).
    pub once: bool,
}

impl Default for WatchOpts {
    fn default() -> Self {
        Self { interval: Duration::from_secs(2), once: false }
    }
}

/// Per-cell progress folded from the event stream.
#[derive(Debug, Clone, Default)]
pub struct CellProgress {
    /// Trial budget announced by the cell's `RunStarted` (0 until seen).
    pub budget: usize,
    /// Evaluated trial groups so far.
    pub trials: usize,
    /// Best speedup promoted so far (1.0 = baseline).
    pub best: f64,
    pub finished: bool,
}

/// Everything one journal-mode snapshot renders: the fold-order-
/// independent [`EventStats`] aggregate plus per-cell progress. Pure
/// data — [`WatchState::fold`] consumes events, [`render_events`]
/// turns it into the dashboard text — so tests drive it without a
/// filesystem or a clock.
#[derive(Debug, Clone, Default)]
pub struct WatchState {
    pub stats: EventStats,
    pub cells: BTreeMap<CellKey, CellProgress>,
}

impl WatchState {
    pub fn fold(&mut self, ev: &crate::store::TrialEvent) {
        self.stats.fold(ev);
        let cell = self.cells.entry(ev.cell()).or_default();
        match &ev.kind {
            TrialEventKind::RunStarted { budget, .. } => cell.budget = *budget,
            TrialEventKind::EvalOutcome { trial, .. } => {
                // Trials are 0-based and replayed resume trials are
                // suppressed upstream, so the count is trial+1.
                cell.trials = cell.trials.max(trial + 1);
            }
            TrialEventKind::NewBest { speedup, .. } => {
                cell.best = cell.best.max(*speedup);
            }
            TrialEventKind::RunFinished { trials, best_speedup, .. } => {
                cell.finished = true;
                cell.trials = cell.trials.max(*trials);
                cell.best = cell.best.max(*best_speedup);
            }
            _ => {}
        }
    }

    /// Trial groups still owed by cells that have started but not
    /// finished (the ETA numerator).
    pub fn remaining_trials(&self) -> usize {
        self.cells
            .values()
            .filter(|c| !c.finished)
            .map(|c| c.budget.saturating_sub(c.trials))
            .sum()
    }
}

const BAR_WIDTH: usize = 20;
/// Unfinished cells listed before the "(+N more)" elision.
const MAX_CELL_ROWS: usize = 24;

fn progress_bar(done: usize, total: usize) -> String {
    let filled = if total == 0 { 0 } else { (done * BAR_WIDTH / total).min(BAR_WIDTH) };
    format!("[{}{}]", "#".repeat(filled), ".".repeat(BAR_WIDTH - filled))
}

fn eta_line(remaining: usize, rate: Option<f64>) -> String {
    match rate {
        Some(r) if r > 0.0 && remaining > 0 => {
            let secs = remaining as f64 / r;
            format!(
                "eta: ~{} at {r:.1} trials/s ({remaining} trial groups remaining)\n",
                fmt_secs(secs)
            )
        }
        _ if remaining == 0 => "eta: all started cells finished\n".to_string(),
        _ => format!("eta: n/a ({remaining} trial groups remaining, rate unknown)\n"),
    }
}

fn fmt_secs(secs: f64) -> String {
    if secs >= 3600.0 {
        format!("{:.1}h", secs / 3600.0)
    } else if secs >= 60.0 {
        format!("{:.1}m", secs / 60.0)
    } else {
        format!("{secs:.0}s")
    }
}

/// Render the journal-mode dashboard. `rate` is the observed trial
/// throughput (trial groups per second) since the watch began, `None`
/// before a meaningful sample exists.
pub fn render_events(target: &str, state: &WatchState, rate: Option<f64>) -> String {
    let s = &state.stats;
    let mut out = String::with_capacity(2048);
    out.push_str(&format!("CAMPAIGN WATCH — {target}\n"));
    out.push_str(&format!(
        "runs: {} started, {} finished ({} with a valid kernel), best {:.2}x\n",
        s.runs_started, s.runs_finished, s.runs_with_valid, s.best_speedup
    ));
    out.push_str(&format!(
        "trials: {} groups evaluated, {} new bests, {} prompt + {} completion tokens\n",
        s.groups, s.new_bests, s.prompt_tokens, s.completion_tokens
    ));
    // Stage-aware validity: every evaluated group ends in exactly one
    // outcome label, so the percentages split the bar completely.
    out.push_str("validity by stage:");
    if s.outcomes.is_empty() {
        out.push_str(" (no trials yet)\n");
    } else {
        for (label, count) in &s.outcomes {
            let pct = 100.0 * *count as f64 / s.groups.max(1) as f64;
            out.push_str(&format!("  {label} {count} ({pct:.1}%)"));
        }
        out.push('\n');
        if s.guard_failed + s.repair_attempts > 0 {
            out.push_str(&format!(
                "stage-0: {} guard failures, {} repair attempts ({} mended)\n",
                s.guard_failed, s.repair_attempts, s.repairs_mended
            ));
        }
    }
    out.push_str(&eta_line(state.remaining_trials(), rate));

    let live: Vec<(&CellKey, &CellProgress)> =
        state.cells.iter().filter(|(_, c)| !c.finished).collect();
    out.push_str(&format!(
        "cells: {} started, {} finished, {} in flight\n",
        state.cells.len(),
        state.cells.len() - live.len(),
        live.len()
    ));
    for ((method, model, op, seed), cell) in live.iter().take(MAX_CELL_ROWS) {
        out.push_str(&format!(
            "  {} {:>3}/{:<3} {} {method} / {model} / {op} / seed {seed}\n",
            progress_bar(cell.trials, cell.budget),
            cell.trials,
            cell.budget,
            if cell.best > 0.0 { format!("{:>5.2}x", cell.best) } else { "    -".into() },
        ));
    }
    if live.len() > MAX_CELL_ROWS {
        out.push_str(&format!("  (+{} more)\n", live.len() - MAX_CELL_ROWS));
    }
    out
}

/// Render the coordinator-mode dashboard from a `GET /status` reply.
/// `rate` is completed cells per second since the watch began.
pub fn render_status(target: &str, v: &Json, rate: Option<f64>) -> String {
    let n = |key: &str| v.get(key).and_then(|x| x.as_u64()).unwrap_or(0);
    let grid = n("grid");
    let done = n("done");
    let mut out = String::with_capacity(1024);
    out.push_str(&format!("CAMPAIGN WATCH — {target} (coordinator)\n"));
    out.push_str(&format!(
        "grid: {done}/{grid} cells done ({} resumed from checkpoint){}\n",
        n("resumed"),
        if v.get("failed").and_then(|f| f.as_bool()) == Some(true) {
            " — SWEEP FAILED"
        } else {
            ""
        }
    ));
    out.push_str(&format!(
        "claims: {} issued, {} re-offered; completions: {} accepted, {} duplicate/stale\n",
        n("claims"),
        n("reclaims"),
        n("completions"),
        n("duplicate_completions")
    ));
    out.push_str(&format!(
        "events: {} buffered in {} batches ({} stale rejected)\n",
        n("events"),
        n("event_batches"),
        n("stale_event_batches")
    ));
    out.push_str(&format!(
        "merged: {} eval-cache lines, {} transcript lines\n",
        n("eval_lines_merged"),
        n("transcript_lines_merged")
    ));
    let remaining = grid.saturating_sub(done) as usize;
    match rate {
        Some(r) if r > 0.0 && remaining > 0 => out.push_str(&format!(
            "eta: ~{} at {r:.2} cells/s ({remaining} cells remaining)\n",
            fmt_secs(remaining as f64 / r)
        )),
        _ if remaining == 0 => out.push_str("eta: sweep drained\n"),
        _ => out.push_str(&format!("eta: n/a ({remaining} cells remaining)\n")),
    }
    out
}

/// ANSI home+clear prefix for the refreshing (non-`--once`) display.
const CLEAR: &str = "\x1b[2J\x1b[H";

/// Watch a sweep at `target`: an `events.jsonl` path, or a
/// `campaign serve` coordinator URL (anything starting `http://` /
/// `https://`).
pub fn watch(target: &str, opts: &WatchOpts) -> Result<()> {
    if target.starts_with("http://") || target.starts_with("https://") {
        watch_coordinator(target, opts)
    } else {
        watch_journal(Path::new(target), opts)
    }
}

fn watch_journal(path: &Path, opts: &WatchOpts) -> Result<()> {
    if !path.exists() {
        return Err(eyre!(
            "event journal {} does not exist (start the campaign with --events, or pass \
             the coordinator URL)",
            path.display()
        ));
    }
    let target = path.display().to_string();
    let mut state = WatchState::default();
    let mut offset = 0u64;
    let started = Instant::now();
    let mut groups_at_start = None;
    loop {
        let (lines, new_off) = super::wire::read_delta(path, offset)?;
        offset = new_off;
        for line in &lines {
            match json::parse(line).map_err(|e| eyre!("{e}")).and_then(|v| {
                events::event_from_json(&v)
            }) {
                Ok(ev) => state.fold(&ev),
                // Torn/corrupt interior lines are advisory everywhere
                // else in the store layer; a watcher must not die on
                // them either.
                Err(e) => eprintln!("warning: skipping bad event line: {e}"),
            }
        }
        // Throughput is measured from the first snapshot's baseline so
        // a watch attached mid-sweep doesn't count pre-existing trials
        // as instant work.
        let base = *groups_at_start.get_or_insert(state.stats.groups);
        let elapsed = started.elapsed().as_secs_f64();
        let rate = (elapsed > 0.5 && state.stats.groups > base)
            .then(|| (state.stats.groups - base) as f64 / elapsed);
        let frame = render_events(&target, &state, rate);
        if opts.once {
            print!("{frame}");
            return Ok(());
        }
        print!("{CLEAR}{frame}");
        use std::io::Write as _;
        std::io::stdout().flush().ok();
        std::thread::sleep(opts.interval);
    }
}

fn watch_coordinator(url: &str, opts: &WatchOpts) -> Result<()> {
    let base = split_url(url)?;
    let timeout = Duration::from_secs(10);
    let started = Instant::now();
    let mut done_at_start = None;
    let mut was_reachable = false;
    loop {
        let reply = request_json(&base, "GET", "/status", "", timeout);
        let v = match reply {
            Ok((200, text)) => json::parse(&text)
                .map_err(|e| eyre!("coordinator sent unparseable status: {e}"))?,
            Ok((code, text)) => return Err(eyre!("status fetch failed: HTTP {code} {text}")),
            Err(_) if was_reachable => {
                // The sweep drained and the coordinator exited — the
                // normal end of a watch, not an error.
                println!("coordinator at {url} went away (sweep likely drained)");
                return Ok(());
            }
            Err(e) => return Err(e.context(format!("coordinator at {url} is not answering"))),
        };
        was_reachable = true;
        let done = v.get("done").and_then(|d| d.as_u64()).unwrap_or(0);
        let grid = v.get("grid").and_then(|g| g.as_u64()).unwrap_or(0);
        let base_done = *done_at_start.get_or_insert(done);
        let elapsed = started.elapsed().as_secs_f64();
        let rate =
            (elapsed > 0.5 && done > base_done).then(|| (done - base_done) as f64 / elapsed);
        let frame = render_status(url, &v, rate);
        if opts.once {
            print!("{frame}");
            return Ok(());
        }
        print!("{CLEAR}{frame}");
        use std::io::Write as _;
        std::io::stdout().flush().ok();
        if grid > 0 && done >= grid {
            println!("sweep drained ({done}/{grid} cells)");
            return Ok(());
        }
        std::thread::sleep(opts.interval);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::{TrialEvent, TrialEventKind};

    fn ev(op: &str, seed: u64, kind: TrialEventKind) -> TrialEvent {
        TrialEvent {
            method: "EvoEngineer-Free".into(),
            model: "GPT-4.1".into(),
            op: op.into(),
            seed,
            kind,
        }
    }

    fn sample_state() -> WatchState {
        let mut state = WatchState::default();
        let stream = vec![
            ev("relu_64", 0, TrialEventKind::RunStarted { budget: 10, provider: "sim".into() }),
            ev("relu_64", 0, TrialEventKind::TrialStarted { trial: 0 }),
            ev(
                "relu_64",
                0,
                TrialEventKind::EvalOutcome {
                    trial: 0,
                    outcome: "ok".into(),
                    speedup: 1.4,
                    prompt_tokens: 100,
                    completion_tokens: 40,
                    src_hash: "aa".into(),
                },
            ),
            ev("relu_64", 0, TrialEventKind::NewBest { trial: 0, speedup: 1.4 }),
            ev(
                "relu_64",
                0,
                TrialEventKind::EvalOutcome {
                    trial: 1,
                    outcome: "compile_fail".into(),
                    speedup: 0.0,
                    prompt_tokens: 100,
                    completion_tokens: 40,
                    src_hash: "bb".into(),
                },
            ),
            ev("gemm_256", 1, TrialEventKind::RunStarted { budget: 10, provider: "sim".into() }),
            ev(
                "gemm_256",
                1,
                TrialEventKind::EvalOutcome {
                    trial: 0,
                    outcome: "ok".into(),
                    speedup: 1.1,
                    prompt_tokens: 90,
                    completion_tokens: 30,
                    src_hash: "cc".into(),
                },
            ),
            ev(
                "gemm_256",
                1,
                TrialEventKind::RunFinished { trials: 10, best_speedup: 2.5, any_valid: true },
            ),
        ];
        for e in &stream {
            state.fold(e);
        }
        state
    }

    #[test]
    fn fold_tracks_per_cell_progress_and_remaining() {
        let state = sample_state();
        assert_eq!(state.cells.len(), 2);
        let relu = &state.cells[&(
            "EvoEngineer-Free".into(),
            "GPT-4.1".into(),
            "relu_64".into(),
            0u64,
        )];
        assert_eq!(relu.budget, 10);
        assert_eq!(relu.trials, 2);
        assert!((relu.best - 1.4).abs() < 1e-12);
        assert!(!relu.finished);
        let gemm = &state.cells[&(
            "EvoEngineer-Free".into(),
            "GPT-4.1".into(),
            "gemm_256".into(),
            1u64,
        )];
        assert!(gemm.finished);
        assert_eq!(gemm.trials, 10);
        // Only the unfinished cell owes trials: 10 - 2 = 8.
        assert_eq!(state.remaining_trials(), 8);
    }

    #[test]
    fn render_events_shows_progress_validity_and_eta() {
        let state = sample_state();
        let out = render_events("events.jsonl", &state, Some(2.0));
        assert!(out.contains("CAMPAIGN WATCH — events.jsonl"), "{out}");
        assert!(out.contains("runs: 2 started, 1 finished (1 with a valid kernel)"), "{out}");
        assert!(out.contains("ok 2 (66.7%)"), "{out}");
        assert!(out.contains("compile_fail 1 (33.3%)"), "{out}");
        // 8 remaining at 2/s = ~4s.
        assert!(out.contains("eta: ~4s at 2.0 trials/s (8 trial groups remaining)"), "{out}");
        assert!(out.contains("cells: 2 started, 1 finished, 1 in flight"), "{out}");
        assert!(out.contains("relu_64 / seed 0"), "{out}");
        // Finished cells are not listed as in-flight rows.
        assert!(!out.contains("gemm_256 / seed 1"), "{out}");
        // No rate sample yet: the ETA degrades gracefully.
        let out = render_events("events.jsonl", &state, None);
        assert!(out.contains("eta: n/a (8 trial groups remaining"), "{out}");
    }

    #[test]
    fn render_status_reads_coordinator_counters() {
        let v = json::parse(
            r#"{"grid":108,"resumed":12,"done":54,"claims":60,"reclaims":2,
                "completions":54,"duplicate_completions":1,"event_batches":88,
                "stale_event_batches":3,"events":1234,"eval_lines_merged":456,
                "transcript_lines_merged":78,"failed":false}"#,
        )
        .unwrap();
        let out = render_status("http://h:1", &v, Some(0.5));
        assert!(out.contains("grid: 54/108 cells done (12 resumed"), "{out}");
        assert!(out.contains("claims: 60 issued, 2 re-offered"), "{out}");
        assert!(out.contains("54 accepted, 1 duplicate/stale"), "{out}");
        assert!(out.contains("1234 buffered in 88 batches (3 stale rejected)"), "{out}");
        assert!(out.contains("456 eval-cache lines, 78 transcript lines"), "{out}");
        // 54 remaining at 0.5/s = 108s = 1.8m.
        assert!(out.contains("eta: ~1.8m at 0.50 cells/s (54 cells remaining)"), "{out}");
        let failed = json::parse(
            &v.to_string().replace("\"failed\":false", "\"failed\":true"),
        )
        .unwrap();
        let out = render_status("http://h:1", &failed, None);
        assert!(out.contains("SWEEP FAILED"), "{out}");
    }

    #[test]
    fn progress_bar_is_bounded() {
        assert_eq!(progress_bar(0, 10), format!("[{}]", ".".repeat(BAR_WIDTH)));
        assert_eq!(progress_bar(10, 10), format!("[{}]", "#".repeat(BAR_WIDTH)));
        assert_eq!(progress_bar(5, 0), format!("[{}]", ".".repeat(BAR_WIDTH)));
        // Overshoot (resumed cell reporting beyond budget) stays capped.
        assert_eq!(progress_bar(15, 10), format!("[{}]", "#".repeat(BAR_WIDTH)));
    }
}
