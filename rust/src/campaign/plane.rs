//! The `WorkPlane` seam (DESIGN.md §15): where campaign workers get
//! cells from and where they put results.
//!
//! `campaign::run`'s thread-scope used to own cell claiming, record
//! collection and failure propagation inline; extracting them behind
//! [`WorkPlane`] lets the same [`worker_loop`] drive two transports:
//!
//! * [`LocalPlane`] — the in-process queue (an atomic claim index over
//!   a shared job slice), byte-identical in behaviour to the inlined
//!   loop it replaced;
//! * `WirePlane` ([`super::wire`]) — cells claimed from a `campaign
//!   serve` coordinator over HTTP/JSON, events and record uploads
//!   streamed back.
//!
//! Locking is poison-tolerant throughout ([`lock_tolerant`]): a worker
//! that panics mid-cell must surface the sweep's typed first error,
//! not cascade `PoisonError` panics across the whole thread scope.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use crate::evals::Evaluator;
use crate::feedback::FeedbackConfig;
use crate::llm::{ModelProfile, Provider};
use crate::methods::engine::{self, EngineOpts, EventSink, Interrupted, TrialGate};
use crate::methods::{Archive, KernelRunRecord, Method, RepairPolicy, RunCtx};
use crate::store::events;
use crate::tasks::OpTask;
use crate::Result;

use super::{results, Job};

/// Lock a mutex, recovering the data from a poisoned lock instead of
/// panicking: the shared campaign state (first error, checkpoint
/// appender, output slots) stays readable after a worker panic, so the
/// sweep reports its typed first error instead of a poison cascade.
pub(crate) fn lock_tolerant<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// One claimed grid cell, fully resolved and ready to drive: the
/// method/model/op/seed identity plus the per-claim engine plumbing
/// (event sinks, warm-resume verification state).
pub struct ClaimedCell {
    /// Grid index of the cell on the plane that issued it.
    pub idx: usize,
    /// Claim generation: a re-offered cell (prior claimant presumed
    /// dead) gets a higher epoch, and the coordinator drops event
    /// uploads from stale epochs.
    pub epoch: u64,
    pub method: Arc<dyn Method>,
    pub model: &'static ModelProfile,
    pub op: OpTask,
    pub seed: u64,
    /// This cell resumes a half-finished prior run whose events are
    /// already journaled (suppress the duplicate `RunStarted` and the
    /// replayed trials' events — DESIGN.md §13).
    pub resumed: bool,
    /// `(trial, src_hash)` pairs from the prior run, verified against
    /// the replayed trials' emissions.
    pub verify_replay: Vec<(usize, String)>,
    /// Event receivers for this cell (shared journal/progress sinks on
    /// the local plane; a per-cell wire sink on the remote one).
    pub sinks: Vec<Arc<dyn EventSink>>,
}

impl ClaimedCell {
    /// The cell's grid identity (checkpoint / event-journal key).
    pub fn key(&self) -> events::CellKey {
        (
            self.method.name(),
            self.model.name.to_string(),
            self.op.name.clone(),
            self.seed,
        )
    }

    /// Human-readable cell label for error context.
    pub fn describe(&self) -> String {
        format!(
            "cell {} / {} / {} / seed {}",
            self.method.name(),
            self.model.name,
            self.op.name,
            self.seed
        )
    }
}

/// Where workers get cells and put results. Implementations are shared
/// across worker threads and must serialize internally.
pub trait WorkPlane: Send + Sync {
    /// Claim the next cell, `None` when the plane is drained (or has
    /// stopped issuing work after a failure/interruption).
    fn claim(&self) -> Result<Option<ClaimedCell>>;

    /// Deliver a completed cell's record.
    fn complete(&self, cell: &ClaimedCell, rec: KernelRunRecord) -> Result<()>;

    /// The trial gate interrupted this cell mid-run (simulated worker
    /// death): the cell is left incomplete for a later resume/re-claim.
    fn interrupt(&self, cell: &ClaimedCell);

    /// The cell failed with a real error; the sweep should abort.
    fn fail(&self, cell: &ClaimedCell, err: anyhow::Error);
}

/// Everything a worker needs besides the plane: the evaluator stack
/// and the per-sweep engine knobs. Shared by reference across the
/// worker threads of one process.
pub struct WorkerEnv<'a> {
    pub evaluator: &'a Evaluator,
    pub archive: &'a Archive,
    pub provider: Arc<dyn Provider>,
    pub budget: usize,
    pub repair: RepairPolicy,
    pub feedback: FeedbackConfig,
    pub prefetch: usize,
    pub trial_gate: Option<Arc<TrialGate>>,
    /// Deposit-side kernel bank: elites that beat the incumbent are
    /// journaled here. `None` = deposits off.
    pub bank: Option<Arc<crate::bank::KernelBank>>,
    /// Consumption-side warm-start snapshot: read-only bank driving
    /// population seeding and retrieval-seeded prompts. `None` = cold.
    pub warm: Option<Arc<crate::bank::KernelBank>>,
}

/// The worker loop both transports share: claim a cell, drive it
/// through the engine, report the outcome, repeat until the plane
/// stops issuing work. Returns the first claim/delivery transport
/// error (local planes never produce one).
pub fn worker_loop(plane: &dyn WorkPlane, env: &WorkerEnv) -> Result<()> {
    loop {
        let Some(cell) = plane.claim()? else {
            return Ok(());
        };
        let ctx = RunCtx {
            evaluator: env.evaluator,
            task: &cell.op,
            model: cell.model,
            seed: cell.seed,
            archive: env.archive,
            budget: env.budget,
            repair: env.repair,
            feedback: env.feedback,
            provider: env.provider.as_ref(),
            bank: env.bank.clone(),
            warm: env.warm.clone(),
        };
        let opts = EngineOpts {
            sinks: cell.sinks.clone(),
            prefetch: env.prefetch,
            trial_gate: env.trial_gate.clone(),
            resumed: cell.resumed,
            verify_replay: cell.verify_replay.clone(),
        };
        match engine::drive(cell.method.as_ref(), &ctx, &opts) {
            Ok(rec) => plane.complete(&cell, rec)?,
            Err(e) if e.downcast_ref::<Interrupted>().is_some() => {
                // Mid-cell simulated kill: the cell is not completed;
                // a resume (or a re-claim on the wire plane) finishes
                // it at trial granularity.
                plane.interrupt(&cell);
                return Ok(());
            }
            Err(e) => {
                plane.fail(&cell, e);
                return Ok(());
            }
        }
    }
}

// ---------------------------------------------------------------------
// LocalPlane: the in-process queue

/// The in-process plane: an atomic claim index over the job slice,
/// records collected into index-addressed slots, first failure /
/// interruption latched in shared flags. Exactly the state the
/// pre-refactor `campaign::run` thread-scope owned inline.
pub(crate) struct LocalPlane<'a> {
    jobs: &'a [Job],
    verify_replay: &'a HashMap<events::CellKey, Vec<(usize, String)>>,
    sinks: Vec<Arc<dyn EventSink>>,
    /// Claim at most this many cells (0 = no cap): the simulated
    /// cell-boundary kill ([`super::CampaignConfig::stop_after`]).
    stop_after: usize,
    quiet: bool,
    next: AtomicUsize,
    done: AtomicUsize,
    out: Mutex<Vec<Option<KernelRunRecord>>>,
    appender: Option<Mutex<results::Appender>>,
    failed: AtomicBool,
    interrupted: AtomicBool,
    first_error: Mutex<Option<anyhow::Error>>,
}

impl<'a> LocalPlane<'a> {
    pub(crate) fn new(
        jobs: &'a [Job],
        verify_replay: &'a HashMap<events::CellKey, Vec<(usize, String)>>,
        sinks: Vec<Arc<dyn EventSink>>,
        stop_after: usize,
        quiet: bool,
        appender: Option<Mutex<results::Appender>>,
    ) -> Self {
        Self {
            jobs,
            verify_replay,
            sinks,
            stop_after,
            quiet,
            next: AtomicUsize::new(0),
            done: AtomicUsize::new(0),
            out: Mutex::new(vec![None; jobs.len()]),
            appender,
            failed: AtomicBool::new(false),
            interrupted: AtomicBool::new(false),
            first_error: Mutex::new(None),
        }
    }

    /// The sweep's first real error, if any (taken once).
    pub(crate) fn take_error(&self) -> Option<anyhow::Error> {
        lock_tolerant(&self.first_error).take()
    }

    /// Record a transport-level worker error. Unreachable for the
    /// in-process plane (claim/complete are infallible); kept for
    /// defensive parity with the wire plane's worker loop.
    pub(crate) fn transport_error(&self, err: anyhow::Error) {
        self.failed.store(true, Ordering::Relaxed);
        let mut g = lock_tolerant(&self.first_error);
        if g.is_none() {
            *g = Some(err);
        }
    }

    pub(crate) fn was_interrupted(&self) -> bool {
        self.interrupted.load(Ordering::Relaxed)
    }

    /// Consume the plane and collect the completed records.
    pub(crate) fn into_completed(self) -> Vec<KernelRunRecord> {
        self.out
            .into_inner()
            .unwrap_or_else(|poisoned| poisoned.into_inner())
            .into_iter()
            .flatten()
            .collect()
    }
}

impl WorkPlane for LocalPlane<'_> {
    fn claim(&self) -> Result<Option<ClaimedCell>> {
        if self.failed.load(Ordering::Relaxed) || self.interrupted.load(Ordering::Relaxed) {
            return Ok(None); // another worker hit a failure / simulated kill
        }
        let idx = self.next.fetch_add(1, Ordering::Relaxed);
        if idx >= self.jobs.len() {
            return Ok(None);
        }
        if self.stop_after > 0 && idx >= self.stop_after {
            // Simulated cell-boundary kill: the claim gate makes the
            // completed-cell count exactly min(stop_after, grid), with
            // no completion-count race.
            return Ok(None);
        }
        let job = &self.jobs[idx];
        let journaled = self.verify_replay.get(&(
            job.method.name(),
            job.model.name.to_string(),
            job.op.name.clone(),
            job.seed,
        ));
        Ok(Some(ClaimedCell {
            idx,
            epoch: 0,
            method: job.method.clone(),
            model: job.model,
            op: job.op.clone(),
            seed: job.seed,
            resumed: journaled.is_some(),
            verify_replay: journaled.cloned().unwrap_or_default(),
            sinks: self.sinks.clone(),
        }))
    }

    fn complete(&self, cell: &ClaimedCell, rec: KernelRunRecord) -> Result<()> {
        if let Some(appender) = &self.appender {
            if let Err(e) = lock_tolerant(appender).append(&rec) {
                eprintln!("warning: checkpoint append failed: {e:#}");
            }
        }
        lock_tolerant(&self.out)[cell.idx] = Some(rec);
        let d = self.done.fetch_add(1, Ordering::Relaxed) + 1;
        if !self.quiet && (d % 200 == 0 || d == self.jobs.len()) {
            eprintln!("  {d}/{} runs complete", self.jobs.len());
        }
        Ok(())
    }

    fn interrupt(&self, _cell: &ClaimedCell) {
        self.interrupted.store(true, Ordering::Relaxed);
    }

    fn fail(&self, cell: &ClaimedCell, err: anyhow::Error) {
        self.failed.store(true, Ordering::Relaxed);
        let mut g = lock_tolerant(&self.first_error);
        if g.is_none() {
            *g = Some(err.context(cell.describe()));
        }
    }
}
