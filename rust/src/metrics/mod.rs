//! Metric aggregation: turns raw [`KernelRunRecord`]s into the numbers
//! every table/figure of the paper reports (§5.1 Evaluation Metric):
//!
//! * **Speedup Count** — per category, the number of kernels whose best
//!   speedup exceeds 1×, averaged over the independent runs.
//! * **Median Speedup Rate** — per category, the median across kernels
//!   of the seed-averaged best speedup, failures counted as 1.0.
//! * **Compilation Success / Functional Correctness (Pass@1)** — the
//!   proportion of *trials* that compile / pass functional testing.
//! * PyTorch-relative speedups for Figure 5 / Table 7 / Figure 8.

use std::collections::BTreeMap;

use crate::methods::KernelRunRecord;
use crate::util::{mean, median};

/// Aggregated cell of Table 4 (one method × model × category).
#[derive(Debug, Clone, Default)]
pub struct Table4Cell {
    pub speedup_count: f64,
    pub median_speedup: f64,
    pub compile_rate: f64,
    pub correct_rate: f64,
    pub n_ops: usize,
}

/// (method, model) group key, ordered for stable output.
pub type GroupKey = (String, String);

/// Group records by (method, model).
pub fn group(records: &[KernelRunRecord]) -> BTreeMap<GroupKey, Vec<&KernelRunRecord>> {
    let mut map: BTreeMap<GroupKey, Vec<&KernelRunRecord>> = BTreeMap::new();
    for r in records {
        map.entry((r.method.clone(), r.model.clone())).or_default().push(r);
    }
    map
}

/// Per-op seed-averaged best speedup (the paper averages the speedup
/// over the three runs before taking the median).
fn per_op_speedups(records: &[&KernelRunRecord]) -> BTreeMap<String, f64> {
    let mut per_op: BTreeMap<String, Vec<f64>> = BTreeMap::new();
    for r in records {
        per_op.entry(r.op.clone()).or_default().push(r.best_speedup);
    }
    per_op.into_iter().map(|(op, v)| (op, mean(&v))).collect()
}

/// Compute one Table-4 cell from a record subset (already filtered to
/// one method × model × category, all seeds).
pub fn table4_cell(records: &[&KernelRunRecord]) -> Table4Cell {
    if records.is_empty() {
        return Table4Cell::default();
    }
    // Speedup count: per seed, count ops beating 1x; then average.
    let mut per_seed: BTreeMap<u64, usize> = BTreeMap::new();
    let mut seeds: Vec<u64> = records.iter().map(|r| r.seed).collect();
    seeds.sort_unstable();
    seeds.dedup();
    for s in &seeds {
        per_seed.insert(*s, 0);
    }
    for r in records {
        if r.best_speedup > 1.0 + 1e-9 && r.any_valid {
            *per_seed.get_mut(&r.seed).unwrap() += 1;
        }
    }
    let speedup_count = mean(&per_seed.values().map(|&c| c as f64).collect::<Vec<_>>());

    let speedups: Vec<f64> = per_op_speedups(records).into_values().collect();
    let median_speedup = median(&speedups);

    let trials: usize = records.iter().map(|r| r.trials).sum();
    let compiled: usize = records.iter().map(|r| r.compiled_trials).sum();
    let correct: usize = records.iter().map(|r| r.correct_trials).sum();
    Table4Cell {
        speedup_count,
        median_speedup,
        compile_rate: 100.0 * compiled as f64 / trials.max(1) as f64,
        correct_rate: 100.0 * correct as f64 / trials.max(1) as f64,
        n_ops: speedups.len(),
    }
}

/// Full Table 4: (method, model) -> [cell per category 1..=6, overall].
pub fn table4(records: &[KernelRunRecord]) -> BTreeMap<GroupKey, Vec<Table4Cell>> {
    let mut out = BTreeMap::new();
    for (key, recs) in group(records) {
        let mut cells = Vec::with_capacity(7);
        for cat in 1..=6u8 {
            let subset: Vec<&KernelRunRecord> =
                recs.iter().copied().filter(|r| r.category == cat).collect();
            cells.push(table4_cell(&subset));
        }
        cells.push(table4_cell(&recs)); // overall
        out.insert(key, cells);
    }
    out
}

/// Aggregate view of a trial-event stream (DESIGN.md §13): what the
/// engine's `MetricsSink` accumulates live and `repro report events`
/// re-derives from an `events.jsonl` journal. Everything here is
/// fold-order-independent, so concurrent campaign workers interleaving
/// their cells' events produce the same stats as a serial sweep.
#[derive(Debug, Clone, Default)]
pub struct EventStats {
    pub runs_started: usize,
    pub runs_finished: usize,
    /// Evaluated trial groups.
    pub groups: usize,
    /// Terminal outcome counts by label ("ok", "compile_fail", …).
    pub outcomes: BTreeMap<String, usize>,
    /// Initial stage-0 verdicts that failed.
    pub guard_failed: usize,
    pub repair_attempts: usize,
    /// Repair attempts whose mended text passed the guard.
    pub repairs_mended: usize,
    pub new_bests: usize,
    pub budget_exhausted: usize,
    pub prompt_tokens: u64,
    pub completion_tokens: u64,
    /// Best speedup any run reported at finish.
    pub best_speedup: f64,
    pub runs_with_valid: usize,
}

impl EventStats {
    /// Fold one event into the aggregate.
    pub fn fold(&mut self, ev: &crate::store::TrialEvent) {
        use crate::store::TrialEventKind as K;
        match &ev.kind {
            K::RunStarted { .. } => self.runs_started += 1,
            K::TrialStarted { .. } => {}
            K::GuardVerdict { pass, .. } => {
                if !pass {
                    self.guard_failed += 1;
                }
            }
            K::RepairAttempt { mended, .. } => {
                self.repair_attempts += 1;
                if *mended {
                    self.repairs_mended += 1;
                }
            }
            K::EvalOutcome { outcome, prompt_tokens, completion_tokens, .. } => {
                self.groups += 1;
                *self.outcomes.entry(outcome.clone()).or_insert(0) += 1;
                self.prompt_tokens += prompt_tokens;
                self.completion_tokens += completion_tokens;
            }
            K::NewBest { .. } => self.new_bests += 1,
            K::BudgetExhausted { .. } => self.budget_exhausted += 1,
            K::RunFinished { best_speedup, any_valid, .. } => {
                self.runs_finished += 1;
                if *any_valid {
                    self.runs_with_valid += 1;
                }
                if *best_speedup > self.best_speedup {
                    self.best_speedup = *best_speedup;
                }
            }
        }
    }

    pub fn from_events(events: &[crate::store::TrialEvent]) -> Self {
        let mut stats = Self::default();
        for ev in events {
            stats.fold(ev);
        }
        stats
    }
}

/// Render an [`EventStats`] aggregate as the `report events` table.
pub fn events_table(stats: &EventStats) -> String {
    let mut out = String::new();
    out.push_str("TRIAL-EVENT SUMMARY (DESIGN.md §13)\n");
    out.push_str(&format!(
        "runs: {} started, {} finished ({} with a valid kernel), {} exhausted their budget\n",
        stats.runs_started, stats.runs_finished, stats.runs_with_valid, stats.budget_exhausted
    ));
    out.push_str(&format!(
        "trial groups: {} evaluated, {} new bests, best speedup {:.2}x\n",
        stats.groups, stats.new_bests, stats.best_speedup
    ));
    out.push_str(&format!(
        "stage-0: {} initial guard failures, {} repair attempts ({} mended)\n",
        stats.guard_failed, stats.repair_attempts, stats.repairs_mended
    ));
    out.push_str(&format!(
        "tokens: {} prompt + {} completion\n",
        stats.prompt_tokens, stats.completion_tokens
    ));
    out.push_str("outcomes:\n");
    for (label, count) in &stats.outcomes {
        let pct = 100.0 * *count as f64 / stats.groups.max(1) as f64;
        out.push_str(&format!("  {label:<16} {count:>8}  ({pct:>5.1}%)\n"));
    }
    out
}

/// One cell of the stage-aware validity breakdown (DESIGN.md §11): the
/// five-way split of trial outcomes, as percentages of the evaluated
/// trial groups (`trials - repair_attempts` — each group ends in
/// exactly one terminal outcome), plus the repaired overlay.
#[derive(Debug, Clone, Default)]
pub struct ValidityCell {
    /// % rejected at stage 0 by the static guard.
    pub stage0_pct: f64,
    /// % whose emission initially failed the guard but was repaired
    /// (overlay: these land in one of the other buckets too).
    pub repaired_pct: f64,
    /// % rejected at stage 1 (compile gate).
    pub compile_fail_pct: f64,
    /// % compiled but functionally wrong (stage 2 / runtime).
    pub incorrect_pct: f64,
    /// % fully correct (the paper's Functional Pass@1).
    pub correct_pct: f64,
    /// Evaluated trial groups behind the percentages.
    pub groups: usize,
}

fn validity_cell(records: &[&KernelRunRecord]) -> ValidityCell {
    if records.is_empty() {
        return ValidityCell::default();
    }
    let groups: usize = records.iter().map(|r| r.trials - r.repair_attempts.min(r.trials)).sum();
    let stage0: usize = records.iter().map(|r| r.guard_rejected_trials).sum();
    let repaired: usize = records.iter().map(|r| r.repaired_trials).sum();
    let compiled: usize = records.iter().map(|r| r.compiled_trials).sum();
    let correct: usize = records.iter().map(|r| r.correct_trials).sum();
    let compile_fail = groups.saturating_sub(stage0).saturating_sub(compiled);
    let incorrect = compiled.saturating_sub(correct);
    let pct = |n: usize| 100.0 * n as f64 / groups.max(1) as f64;
    ValidityCell {
        stage0_pct: pct(stage0),
        repaired_pct: pct(repaired),
        compile_fail_pct: pct(compile_fail),
        incorrect_pct: pct(incorrect),
        correct_pct: pct(correct),
        groups,
    }
}

/// Full stage-aware validity table: (method, model) -> [cell per
/// category 1..=6, overall] — the per-category split the campaign
/// report prints when a repair policy was active.
pub fn validity_table(records: &[KernelRunRecord]) -> BTreeMap<GroupKey, Vec<ValidityCell>> {
    let mut out = BTreeMap::new();
    for (key, recs) in group(records) {
        let mut cells = Vec::with_capacity(7);
        for cat in 1..=6u8 {
            let subset: Vec<&KernelRunRecord> =
                recs.iter().copied().filter(|r| r.category == cat).collect();
            cells.push(validity_cell(&subset));
        }
        cells.push(validity_cell(&recs)); // overall
        out.insert(key, cells);
    }
    out
}

/// One row of the per-goal breakdown (DESIGN.md §17): every record
/// that ran under one `--goal` label, with validity and speedup side
/// by side so a multi-objective campaign's legs compare in one table.
#[derive(Debug, Clone, Default)]
pub struct GoalRow {
    /// The [`FeedbackConfig`](crate::feedback::FeedbackConfig) label
    /// ("speedup", "speedup+profile", "memory", "balanced").
    pub goal: String,
    pub runs: usize,
    /// Runs that found at least one valid improvement.
    pub valid_runs: usize,
    pub median_speedup: f64,
    /// Functionally-correct trials as % of all trials in the row.
    pub correct_pct: f64,
    pub guard_rejected: usize,
    pub prompt_tokens: u64,
    pub completion_tokens: u64,
}

/// Per-goal aggregation in stable label order. Single-goal campaigns
/// produce one row — the caller decides whether that is worth printing.
pub fn goal_table(records: &[KernelRunRecord]) -> Vec<GoalRow> {
    let mut map: BTreeMap<String, Vec<&KernelRunRecord>> = BTreeMap::new();
    for r in records {
        map.entry(r.goal.clone()).or_default().push(r);
    }
    map.into_iter()
        .map(|(goal, recs)| {
            let speedups: Vec<f64> = recs.iter().map(|r| r.best_speedup).collect();
            let trials: usize = recs.iter().map(|r| r.trials).sum();
            let correct: usize = recs.iter().map(|r| r.correct_trials).sum();
            GoalRow {
                goal,
                runs: recs.len(),
                valid_runs: recs.iter().filter(|r| r.any_valid).count(),
                median_speedup: median(&speedups),
                correct_pct: 100.0 * correct as f64 / trials.max(1) as f64,
                guard_rejected: recs.iter().map(|r| r.guard_rejected_trials).sum(),
                prompt_tokens: recs.iter().map(|r| r.prompt_tokens).sum(),
                completion_tokens: recs.iter().map(|r| r.completion_tokens).sum(),
            }
        })
        .collect()
}

/// Per-(provider, model) token usage and modeled API cost — the
/// provider-seam accounting surfaced by `repro report tokens`
/// (DESIGN.md §12). Replayed records carry the label of the backend
/// that generated them, so replay never double-counts as a new
/// provider.
#[derive(Debug, Clone)]
pub struct TokenCostRow {
    pub provider: String,
    pub model: String,
    pub runs: usize,
    pub prompt_tokens: u64,
    pub completion_tokens: u64,
    /// Modeled API cost at the paper's Table 6 per-Mtok pricing
    /// ([`ModelProfile::cost_usd`]). `None` for rows whose tokens the
    /// Table 6 rates do not describe: anything not generated by the
    /// sim backend (an HTTP endpoint's real pricing is unknown — the
    /// record's `model` is the simulated profile name, not the remote
    /// model id), or a model with no known profile.
    ///
    /// [`ModelProfile::cost_usd`]: crate::llm::ModelProfile::cost_usd
    pub cost_usd: Option<f64>,
    /// Median best speedup across the row's runs — the quality axis of
    /// the cost/quality frontier `report tokens` renders.
    pub median_speedup: f64,
    /// Functionally-correct trials as % of all trials in the row.
    pub correct_pct: f64,
}

impl TokenCostRow {
    pub fn total_tokens(&self) -> u64 {
        self.prompt_tokens + self.completion_tokens
    }
}

/// Is this provider label priced at the paper's Table 6 rates? True
/// for the sim backend and for ensemble labels whose every member is
/// the sim backend (their tokens all came from simulated models);
/// false for anything with live-endpoint tokens in it.
fn sim_priced(provider: &str) -> bool {
    if provider == "sim" {
        return true;
    }
    match crate::llm::ProviderSpec::parse(provider) {
        Ok(crate::llm::ProviderSpec::Ensemble(spec)) => spec
            .members
            .iter()
            .all(|m| matches!(m.backend, crate::llm::MemberBackend::Sim)),
        _ => false,
    }
}

/// Aggregate token/cost accounting per (provider, model), in stable
/// (provider, model) order.
pub fn token_cost_table(records: &[KernelRunRecord]) -> Vec<TokenCostRow> {
    let mut map: BTreeMap<(String, String), Vec<&KernelRunRecord>> = BTreeMap::new();
    for r in records {
        map.entry((r.provider.clone(), r.model.clone())).or_default().push(r);
    }
    map.into_iter()
        .map(|((provider, model), recs)| {
            let prompt_tokens: u64 = recs.iter().map(|r| r.prompt_tokens).sum();
            let completion_tokens: u64 = recs.iter().map(|r| r.completion_tokens).sum();
            let trials: usize = recs.iter().map(|r| r.trials).sum();
            let correct: usize = recs.iter().map(|r| r.correct_trials).sum();
            let speedups: Vec<f64> = recs.iter().map(|r| r.best_speedup).collect();
            // Table 6 pricing describes the three simulated models
            // only. An "http" row's record.model is still the
            // *profile* name the cell ran as (the endpoint's real
            // model id and pricing are unknown), so pricing it at
            // Table 6 rates would invent a bill; those rows render as
            // unpriced. Replays of sim transcripts impersonate the
            // "sim" label and price normally, as do all-sim ensembles.
            let cost_usd = if sim_priced(&provider) {
                crate::llm::profile::by_name(&model)
                    .map(|p| p.cost_usd(prompt_tokens, completion_tokens))
            } else {
                None
            };
            TokenCostRow {
                provider,
                model,
                runs: recs.len(),
                prompt_tokens,
                completion_tokens,
                cost_usd,
                median_speedup: median(&speedups),
                correct_pct: 100.0 * correct as f64 / trials.max(1) as f64,
            }
        })
        .collect()
}

/// Learned bandit arm state merged across records (DESIGN.md §16):
/// pulls sum, means combine pull-weighted, sorted by
/// (member, operator, category). Empty unless some record ran a
/// multi-member ensemble.
pub fn arm_weight_table(records: &[KernelRunRecord]) -> Vec<crate::llm::ArmWeight> {
    let mut map: BTreeMap<(String, String, String), (u64, f64)> = BTreeMap::new();
    for r in records {
        for a in &r.arms {
            let e = map
                .entry((a.member.clone(), a.operator.clone(), a.category.clone()))
                .or_insert((0, 0.0));
            e.0 += a.pulls;
            e.1 += a.mean_reward * a.pulls as f64;
        }
    }
    map.into_iter()
        .map(|((member, operator, category), (pulls, reward_sum))| crate::llm::ArmWeight {
            member,
            operator,
            category,
            pulls,
            mean_reward: if pulls == 0 { 0.0 } else { reward_sum / pulls as f64 },
        })
        .collect()
}

/// Figure-1 point: overall median speedup vs functional-correctness
/// rate for one (method, model).
#[derive(Debug, Clone)]
pub struct TradeoffPoint {
    pub method: String,
    pub model: String,
    pub median_speedup: f64,
    pub correct_rate: f64,
    pub total_tokens: u64,
}

pub fn tradeoff_points(records: &[KernelRunRecord]) -> Vec<TradeoffPoint> {
    group(records)
        .into_iter()
        .map(|((method, model), recs)| {
            let cell = table4_cell(&recs);
            let tokens: u64 = recs.iter().map(|r| r.total_tokens()).sum();
            TradeoffPoint {
                method,
                model,
                median_speedup: cell.median_speedup,
                correct_rate: cell.correct_rate,
                total_tokens: tokens,
            }
        })
        .collect()
}

/// Per-op best PyTorch-relative speedup across methods/models/seeds,
/// with the winning (method, model) — Figure 5's data.
#[derive(Debug, Clone)]
pub struct PytorchBest {
    pub op: String,
    pub category: u8,
    pub speedup: f64,
    pub method: String,
    pub model: String,
}

pub fn pytorch_best_per_op(records: &[KernelRunRecord]) -> Vec<PytorchBest> {
    let mut best: BTreeMap<String, PytorchBest> = BTreeMap::new();
    for r in records {
        if !r.any_valid {
            continue;
        }
        let entry = best.entry(r.op.clone()).or_insert_with(|| PytorchBest {
            op: r.op.clone(),
            category: r.category,
            speedup: f64::MIN,
            method: String::new(),
            model: String::new(),
        });
        if r.best_pytorch_speedup > entry.speedup {
            entry.speedup = r.best_pytorch_speedup;
            entry.method = r.method.clone();
            entry.model = r.model.clone();
        }
    }
    let mut v: Vec<PytorchBest> = best.into_values().collect();
    v.sort_by(|a, b| b.speedup.partial_cmp(&a.speedup).unwrap());
    v
}

/// Table-7 buckets: <1, 1–2, 2–5, 5–10, >10 (vs PyTorch), per
/// (method, model), using the max over seeds per op.
pub fn speedup_range_distribution(
    records: &[KernelRunRecord],
) -> BTreeMap<GroupKey, [usize; 5]> {
    let mut out = BTreeMap::new();
    for (key, recs) in group(records) {
        let mut per_op: BTreeMap<String, f64> = BTreeMap::new();
        for r in &recs {
            let v = if r.any_valid { r.best_pytorch_speedup } else { 0.0 };
            let e = per_op.entry(r.op.clone()).or_insert(0.0);
            *e = e.max(v);
        }
        let mut buckets = [0usize; 5];
        for (_, s) in per_op {
            let idx = if s < 1.0 {
                0
            } else if s < 2.0 {
                1
            } else if s < 5.0 {
                2
            } else if s < 10.0 {
                3
            } else {
                4
            };
            buckets[idx] += 1;
        }
        out.insert(key, buckets);
    }
    out
}

/// Five-number summary of the per-op max PyTorch speedups for one
/// method (Figure 8's violin stand-in).
#[derive(Debug, Clone)]
pub struct DistSummary {
    pub method: String,
    pub min: f64,
    pub p25: f64,
    pub median: f64,
    pub p75: f64,
    pub max: f64,
    pub n: usize,
}

pub fn method_distributions(records: &[KernelRunRecord]) -> Vec<DistSummary> {
    let mut by_method: BTreeMap<String, BTreeMap<String, f64>> = BTreeMap::new();
    for r in records {
        let v = if r.any_valid { r.best_pytorch_speedup } else { 0.0 };
        let e = by_method
            .entry(r.method.clone())
            .or_default()
            .entry(r.op.clone())
            .or_insert(0.0);
        *e = e.max(v);
    }
    by_method
        .into_iter()
        .map(|(method, per_op)| {
            let xs: Vec<f64> = per_op.into_values().collect();
            DistSummary {
                method,
                min: crate::util::percentile(&xs, 0.0),
                p25: crate::util::percentile(&xs, 25.0),
                median: crate::util::percentile(&xs, 50.0),
                p75: crate::util::percentile(&xs, 75.0),
                max: crate::util::percentile(&xs, 100.0),
                n: xs.len(),
            }
        })
        .collect()
}

/// Table-8 style summary for one method's records (the AI CUDA
/// Engineer replication numbers).
#[derive(Debug, Clone)]
pub struct ReplicationSummary {
    pub median_speedup_all: f64,
    pub median_speedup_success: f64,
    pub successful_tasks: usize,
    pub n_ops: usize,
}

pub fn replication_summary(records: &[KernelRunRecord], method: &str) -> ReplicationSummary {
    let recs: Vec<&KernelRunRecord> =
        records.iter().filter(|r| r.method == method).collect();
    let mut per_op: BTreeMap<String, Vec<f64>> = BTreeMap::new();
    for r in &recs {
        per_op
            .entry(r.op.clone())
            .or_default()
            .push(if r.any_valid { r.best_pytorch_speedup } else { 0.5 });
    }
    let per_op_avg: Vec<f64> = per_op.values().map(|v| mean(v)).collect();
    let successes: Vec<f64> = per_op_avg.iter().copied().filter(|&s| s > 1.0).collect();
    ReplicationSummary {
        median_speedup_all: median(&per_op_avg),
        median_speedup_success: median(&successes),
        successful_tasks: successes.len(),
        n_ops: per_op_avg.len(),
    }
}

/// Figure-9 data: paired per-op speedups from two disjoint seed sets of
/// the same method (our replication-vs-archive correlation proxy; see
/// EXPERIMENTS.md).
pub fn replication_pairs(
    records: &[KernelRunRecord],
    method: &str,
    seed_a: u64,
    seed_b: u64,
) -> (Vec<f64>, Vec<f64>) {
    let mut a: BTreeMap<String, f64> = BTreeMap::new();
    let mut b: BTreeMap<String, f64> = BTreeMap::new();
    for r in records.iter().filter(|r| r.method == method) {
        let v = r.best_speedup;
        if r.seed == seed_a {
            a.insert(r.op.clone(), v);
        } else if r.seed == seed_b {
            b.insert(r.op.clone(), v);
        }
    }
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for (op, va) in &a {
        if let Some(vb) = b.get(op) {
            xs.push(va.ln());
            ys.push(vb.ln());
        }
    }
    (xs, ys)
}

/// Counters of one distributed sweep's work plane (`campaign serve`,
/// DESIGN.md §15): how the grid was claimed, streamed, and merged.
/// Rendered by [`crate::report::plane`] and served live by the
/// coordinator's `GET /status`.
#[derive(Debug, Clone, Default)]
pub struct PlaneStats {
    /// Grid cells the coordinator offered (after op/seed filters).
    pub grid: usize,
    /// Cells pre-filled from a prior checkpoint on `--resume`.
    pub resumed: usize,
    /// Successful cell claims handed to workers (re-claims included).
    pub claims: u64,
    /// Cells released mid-run and re-offered at a higher epoch.
    pub reclaims: u64,
    /// Records accepted (each cell completes exactly once).
    pub completions: u64,
    /// Completions rejected as duplicate or stale-epoch.
    pub duplicate_completions: u64,
    /// Event batches accepted into per-cell buffers.
    pub event_batches: u64,
    /// Event batches rejected for a stale epoch or a finished cell.
    pub stale_event_batches: u64,
    /// Trial events accepted across all batches.
    pub events: u64,
    /// Eval-cache journal lines merged from worker uploads (dedup'd).
    pub eval_lines_merged: u64,
    /// Transcript journal lines merged from worker uploads (dedup'd).
    pub transcript_lines_merged: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(method: &str, op: &str, cat: u8, seed: u64, speed: f64, valid: bool) -> KernelRunRecord {
        KernelRunRecord {
            method: method.into(),
            model: "GPT-4.1".into(),
            op: op.into(),
            category: cat,
            seed,
            trials: 45,
            budget: 45,
            compiled_trials: 36,
            correct_trials: 27,
            guard_rejected_trials: 0,
            repaired_trials: 0,
            repair_attempts: 0,
            repair_policy: "off".into(),
            goal: "speedup".into(),
            provider: "sim".into(),
            best_speedup: speed,
            best_pytorch_speedup: if valid { speed * 0.8 } else { 0.0 },
            any_valid: valid,
            prompt_tokens: 100,
            completion_tokens: 50,
            trajectory: vec![],
            best_src: None,
            arms: vec![],
        }
    }

    #[test]
    fn goal_table_groups_by_objective_label() {
        let mut a = rec("M", "a", 1, 0, 2.0, true);
        let mut b = rec("M", "b", 1, 0, 4.0, true);
        b.goal = "balanced".into();
        b.guard_rejected_trials = 3;
        let c = rec("M", "c", 1, 0, 1.0, false);
        a.goal = "speedup".into();
        let rows = goal_table(&[a, b, c]);
        assert_eq!(rows.len(), 2);
        // BTreeMap order: "balanced" sorts before "speedup".
        assert_eq!(rows[0].goal, "balanced");
        assert_eq!(rows[0].runs, 1);
        assert_eq!(rows[0].valid_runs, 1);
        assert_eq!(rows[0].guard_rejected, 3);
        assert!((rows[0].median_speedup - 4.0).abs() < 1e-9);
        assert_eq!(rows[1].goal, "speedup");
        assert_eq!(rows[1].runs, 2);
        assert_eq!(rows[1].valid_runs, 1);
        assert_eq!(rows[1].prompt_tokens, 200);
        assert!((rows[1].correct_pct - 60.0).abs() < 1e-9); // 54/90
    }

    #[test]
    fn token_cost_table_groups_by_provider_and_model() {
        let mut a = rec("M", "a", 1, 0, 2.0, true); // sim / GPT-4.1
        a.prompt_tokens = 1_000_000;
        a.completion_tokens = 1_000_000;
        let mut b = rec("M", "b", 1, 0, 2.0, true);
        b.provider = "http".into();
        // Real pipeline shape: an http cell's record still carries the
        // *profile* name (here GPT-4.1) — it must NOT be priced at
        // Table 6 rates, because the endpoint's actual pricing is
        // unknown.
        let rows = token_cost_table(&[a.clone(), a, b]);
        assert_eq!(rows.len(), 2);
        let http = rows.iter().find(|r| r.provider == "http").unwrap();
        assert_eq!(http.runs, 1);
        assert!(http.cost_usd.is_none(), "http tokens priced at sim Table-6 rates");
        let sim = rows.iter().find(|r| r.provider == "sim").unwrap();
        assert_eq!(sim.runs, 2);
        assert_eq!(sim.prompt_tokens, 2_000_000);
        // 2 Mtok prompt @ $2 + 2 Mtok completion @ $8 = $20.
        assert!((sim.cost_usd.unwrap() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn token_cost_table_prices_all_sim_ensembles_and_carries_quality() {
        let mut a = rec("M", "a", 1, 0, 2.0, true);
        a.provider = "ensemble:[sim@0.5,sim#alt@0.5,x=0.25]".into();
        a.prompt_tokens = 1_000_000;
        a.completion_tokens = 1_000_000;
        let mut b = rec("M", "b", 1, 0, 4.0, true);
        b.provider = "ensemble:[sim@0.5,http@0.5,x=0.25]".into();
        let rows = token_cost_table(&[a, b]);
        assert_eq!(rows.len(), 2);
        let all_sim = rows.iter().find(|r| r.provider.contains("alt")).unwrap();
        // 1 Mtok prompt @ $2 + 1 Mtok completion @ $8 = $10: an
        // all-sim ensemble's tokens are all Table-6 tokens.
        assert!((all_sim.cost_usd.unwrap() - 10.0).abs() < 1e-9);
        assert!((all_sim.median_speedup - 2.0).abs() < 1e-9);
        assert!((all_sim.correct_pct - 60.0).abs() < 1e-9); // 27/45
        let mixed = rows.iter().find(|r| r.provider.contains("http")).unwrap();
        assert!(mixed.cost_usd.is_none(), "http member tokens priced at sim rates");
    }

    #[test]
    fn arm_weight_table_merges_pull_weighted() {
        use crate::llm::ArmWeight;
        let arm = |member: &str, pulls: u64, mean: f64| ArmWeight {
            member: member.into(),
            operator: "mutate".into(),
            category: "matmul".into(),
            pulls,
            mean_reward: mean,
        };
        let mut a = rec("M", "a", 1, 0, 2.0, true);
        a.arms = vec![arm("fast", 3, 1.0), arm("slow", 1, 0.0)];
        let mut b = rec("M", "a", 1, 1, 2.0, true);
        b.arms = vec![arm("fast", 1, 0.2)];
        let plain = rec("M", "b", 1, 0, 2.0, true); // no arms: ignored
        let merged = arm_weight_table(&[a, b, plain]);
        assert_eq!(merged.len(), 2);
        assert_eq!(merged[0].member, "fast");
        assert_eq!(merged[0].pulls, 4);
        // (3*1.0 + 1*0.2) / 4 = 0.8
        assert!((merged[0].mean_reward - 0.8).abs() < 1e-9);
        assert_eq!(merged[1].member, "slow");
        assert_eq!(merged[1].pulls, 1);
    }

    #[test]
    fn cell_rates_and_counts() {
        let records = vec![
            rec("M", "a", 1, 0, 2.0, true),
            rec("M", "a", 1, 1, 3.0, true),
            rec("M", "b", 1, 0, 1.0, false),
            rec("M", "b", 1, 1, 1.5, true),
        ];
        let refs: Vec<&KernelRunRecord> = records.iter().collect();
        let cell = table4_cell(&refs);
        // seed 0: 1 op >1x; seed 1: 2 ops -> 1.5 average
        assert!((cell.speedup_count - 1.5).abs() < 1e-9);
        // per-op means: a = 2.5, b = 1.25 -> median 1.875
        assert!((cell.median_speedup - 1.875).abs() < 1e-9);
        assert!((cell.compile_rate - 80.0).abs() < 1e-9);
        assert!((cell.correct_rate - 60.0).abs() < 1e-9);
    }

    #[test]
    fn validity_cell_five_way_split() {
        let mut r = rec("M", "a", 1, 0, 2.0, true);
        // 45 budget units: 5 repair calls -> 40 evaluated groups.
        // 4 stage-0 rejected, 30 compiled (of which 24 correct),
        // => 40 - 4 - 30 = 6 compile-failed; 3 repaired overlay.
        r.trials = 45;
        r.repair_attempts = 5;
        r.guard_rejected_trials = 4;
        r.compiled_trials = 30;
        r.correct_trials = 24;
        r.repaired_trials = 3;
        let records = vec![r];
        let table = validity_table(&records);
        let cells = table.get(&("M".into(), "GPT-4.1".into())).unwrap();
        let overall = &cells[6];
        assert_eq!(overall.groups, 40);
        assert!((overall.stage0_pct - 10.0).abs() < 1e-9);
        assert!((overall.compile_fail_pct - 15.0).abs() < 1e-9);
        assert!((overall.incorrect_pct - 15.0).abs() < 1e-9);
        assert!((overall.correct_pct - 60.0).abs() < 1e-9);
        assert!((overall.repaired_pct - 7.5).abs() < 1e-9);
        // The four disjoint buckets cover every evaluated group.
        let total = overall.stage0_pct
            + overall.compile_fail_pct
            + overall.incorrect_pct
            + overall.correct_pct;
        assert!((total - 100.0).abs() < 1e-9);
        // Category 1 cell equals overall (single record, category 1);
        // other categories are empty.
        assert_eq!(cells[0].groups, 40);
        assert_eq!(cells[1].groups, 0);
    }

    #[test]
    fn table7_buckets() {
        let records = vec![
            rec("M", "a", 1, 0, 1.0, false), // invalid -> <1 bucket
            rec("M", "b", 1, 0, 1.5, true),  // pt 1.2 -> 1-2
            rec("M", "c", 1, 0, 4.0, true),  // pt 3.2 -> 2-5
            rec("M", "d", 1, 0, 15.0, true), // pt 12 -> >10
        ];
        let d = speedup_range_distribution(&records);
        let buckets = d.get(&("M".into(), "GPT-4.1".into())).unwrap();
        assert_eq!(*buckets, [1, 1, 1, 0, 1]);
    }

    #[test]
    fn pytorch_best_tracks_winner() {
        let mut r1 = rec("M1", "a", 1, 0, 2.0, true);
        r1.best_pytorch_speedup = 3.0;
        let mut r2 = rec("M2", "a", 1, 0, 2.0, true);
        r2.best_pytorch_speedup = 5.0;
        let best = pytorch_best_per_op(&[r1, r2]);
        assert_eq!(best.len(), 1);
        assert_eq!(best[0].method, "M2");
        assert_eq!(best[0].speedup, 5.0);
    }

    #[test]
    fn replication_pairs_align_ops() {
        let records = vec![
            rec("M", "a", 1, 0, 2.0, true),
            rec("M", "a", 1, 1, 2.2, true),
            rec("M", "b", 1, 0, 1.5, true),
            // op b missing for seed 1 -> excluded
        ];
        let (xs, ys) = replication_pairs(&records, "M", 0, 1);
        assert_eq!(xs.len(), 1);
        assert!((xs[0] - 2.0f64.ln()).abs() < 1e-12);
        assert!((ys[0] - 2.2f64.ln()).abs() < 1e-12);
    }
}
