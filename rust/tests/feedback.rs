//! Profile-guided feedback conformance (DESIGN.md §17).
//!
//! The subsystem's two load-bearing contracts, tested end-to-end on
//! real artifacts:
//!
//! 1. **Default-goal byte-identity.** `--goal speedup` (the default)
//!    must behave bit-for-bit like a pre-feedback build: identity
//!    fitness, no profile sections, no `goal` key in serialized
//!    records.
//! 2. **Replay-safe profiles.** A `--goal balanced` campaign recorded
//!    once replays byte-identically with zero live generation — the
//!    profile sections re-render from journaled noise-free numbers, so
//!    every request hash lands on the transcript journal. Prefetch
//!    must not perturb profiled records either (speculative requests
//!    hash-miss instead of carrying stale profiles).

use std::path::PathBuf;
use std::sync::Arc;

use evoengineer::campaign::{self, results, CampaignConfig};
use evoengineer::costmodel::baseline_schedule;
use evoengineer::dsl::{self, KernelSpec};
use evoengineer::evals::Evaluator;
use evoengineer::feedback::{FeedbackConfig, Goal, ProfileReport};
use evoengineer::llm::ProviderSpec;
use evoengineer::methods::KernelRunRecord;
use evoengineer::report;
use evoengineer::runtime::Runtime;
use evoengineer::tasks::TaskRegistry;
use evoengineer::util::Rng;

fn evaluator() -> Evaluator {
    let reg = Arc::new(
        TaskRegistry::load(PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")).unwrap(),
    );
    Evaluator::new(reg, Runtime::new().unwrap())
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "evo_feedback_{tag}_{}_{}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .subsec_nanos()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn base_cfg() -> CampaignConfig {
    CampaignConfig {
        methods: vec!["evoengineer-free".into(), "funsearch".into()],
        models: vec!["gpt".into()],
        seeds: vec![0],
        op_filter: "relu_64".into(),
        budget: 8,
        quiet: true,
        ..CampaignConfig::default()
    }
}

fn record_lines(records: &[KernelRunRecord]) -> Vec<String> {
    records.iter().map(|r| r.to_json().to_string()).collect()
}

#[test]
fn profile_renders_deterministically_from_a_live_evaluation() {
    let ev = evaluator();
    let task = ev.registry.get("matmul_64").unwrap().clone();
    let spec = KernelSpec {
        op: task.name.clone(),
        semantics: "opt".into(),
        schedule: baseline_schedule(&task),
    };
    let src = dsl::print(&spec);
    // Two evaluations with different RNG streams: the measured (noisy)
    // numbers differ, the rendered profile must not — it is built from
    // noise-free quantities only.
    let a = ev.evaluate(&src, &task, &mut Rng::new(1));
    let b = ev.evaluate(&src, &task, &mut Rng::new(999));
    let ra = ProfileReport::from_outcome(&task, &a, &ev.gpu);
    let rb = ProfileReport::from_outcome(&task, &b, &ev.gpu);
    for goal in [Goal::Speedup, Goal::Memory, Goal::Balanced] {
        assert_eq!(ra.render(goal), rb.render(goal), "profile carries measurement noise");
    }
    let text = ra.render(Goal::Balanced);
    assert!(text.contains("op: matmul_64"), "{text}");
    assert!(text.contains("outcome: ok"), "{text}");
    assert!(text.contains("speedup_vs_baseline:"), "{text}");
    assert!(text.contains("bound:"), "{text}");
    assert!(text.contains("arithmetic_intensity:"), "{text}");
    assert!(text.contains("objective: balanced"), "{text}");
}

#[test]
fn default_goal_records_match_an_explicit_speedup_goal_and_omit_the_key() {
    // `--goal speedup` must be indistinguishable from not passing the
    // flag at all — and the serialized records must not grow a `goal`
    // key (pre-feedback readers and byte-identity baselines both
    // depend on it).
    let implicit = campaign::run(&base_cfg(), evaluator()).unwrap();
    let explicit_cfg = CampaignConfig {
        goal: FeedbackConfig::parse("speedup").unwrap(),
        ..base_cfg()
    };
    let explicit = campaign::run(&explicit_cfg, evaluator()).unwrap();
    assert_eq!(record_lines(&implicit), record_lines(&explicit));
    for line in record_lines(&implicit) {
        assert!(!line.contains("\"goal\""), "default-goal record grew a goal key: {line}");
    }
    // The key still round-trips as the default on re-load.
    let dir = tmpdir("default");
    let path = dir.join("r.jsonl");
    results::save(&path, &implicit).unwrap();
    for r in results::load(&path).unwrap() {
        assert_eq!(r.goal, "speedup");
    }
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn balanced_campaign_records_then_replays_byte_identically() {
    let dir = tmpdir("replay");
    let transcripts = dir.join("transcripts.jsonl");
    let goal = FeedbackConfig::parse("balanced").unwrap();

    let rec_cfg = CampaignConfig {
        goal,
        transcripts: Some(transcripts.clone()),
        ..base_cfg()
    };
    let recorded = campaign::run(&rec_cfg, evaluator()).unwrap();
    assert_eq!(recorded.len(), 2);
    assert!(recorded.iter().all(|r| r.goal == "balanced"));
    let journal_bytes = std::fs::read(&transcripts).unwrap();
    assert!(!journal_bytes.is_empty());

    // Replay with zero live generation: the profile sections re-render
    // from journaled numbers, so every request hash (profile and goal
    // fields included) lands on the journal.
    let replay_cfg = CampaignConfig {
        goal,
        provider: ProviderSpec::Replay(transcripts.clone()),
        transcripts: None,
        ..base_cfg()
    };
    let replayed = campaign::run(&replay_cfg, evaluator()).unwrap();
    assert_eq!(record_lines(&recorded), record_lines(&replayed));
    assert_eq!(report::table4(&recorded), report::table4(&replayed));
    assert_eq!(
        journal_bytes,
        std::fs::read(&transcripts).unwrap(),
        "replay must not append to the transcript journal"
    );

    // The per-goal breakdown renders from these records.
    let text = report::goals(&recorded);
    assert!(text.contains("balanced"), "{text}");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn profiled_records_are_stable_across_prefetch() {
    // Speculative prefetch cannot see the in-flight trial's outcome,
    // so with profiles on its requests hash-miss and are regenerated
    // sequentially — a throughput cost, never a record change.
    let cfg_off = CampaignConfig {
        goal: FeedbackConfig::parse("speedup+profile").unwrap(),
        ..base_cfg()
    };
    let cfg_on = CampaignConfig { prefetch: 2, ..cfg_off.clone() };
    let off = campaign::run(&cfg_off, evaluator()).unwrap();
    let on = campaign::run(&cfg_on, evaluator()).unwrap();
    assert_eq!(record_lines(&off), record_lines(&on));
    assert!(off.iter().all(|r| r.goal == "speedup+profile"));
}

#[test]
fn goals_change_search_behaviour_but_stay_deterministic() {
    // Same grid, three objectives: each leg is internally deterministic
    // (run twice, byte-identical), and the recorded labels differ.
    let mut by_goal = Vec::new();
    for label in ["speedup", "memory", "balanced"] {
        let cfg = CampaignConfig {
            goal: FeedbackConfig::parse(label).unwrap(),
            ..base_cfg()
        };
        let a = campaign::run(&cfg, evaluator()).unwrap();
        let b = campaign::run(&cfg, evaluator()).unwrap();
        assert_eq!(record_lines(&a), record_lines(&b), "goal {label} is not deterministic");
        by_goal.push(a);
    }
    let all: Vec<KernelRunRecord> = by_goal.into_iter().flatten().collect();
    let table = evoengineer::metrics::goal_table(&all);
    assert_eq!(table.len(), 3, "three goal labels in the combined records");
}
