//! Property-based tests on coordinator invariants (routing of
//! candidates through the compile gates, population state, DSL
//! round-trips). The environment is offline (no proptest crate), so
//! this is a seeded random-input harness over the same invariants —
//! hundreds of random cases per property, with the failing seed printed
//! for reproduction.

use evoengineer::dsl::{self, KernelSpec, Layout, Schedule};
use evoengineer::population::{Candidate, Elite, Islands, Population, SingleBest};
use evoengineer::util::json;
use evoengineer::util::Rng;

const CASES: u64 = 500;

fn arbitrary_schedule(rng: &mut Rng) -> Schedule {
    Schedule {
        tile_m: *rng.pick(&[1, 4, 8, 16, 32, 64, 128, 256]),
        tile_n: *rng.pick(&[1, 4, 8, 16, 32, 64, 128, 256]),
        tile_k: *rng.pick(&[1, 4, 8, 16, 32, 64, 128, 256]),
        vector_width: *rng.pick(&[1, 2, 4, 8]),
        unroll: *rng.pick(&[1, 2, 4, 8, 16]),
        stages: 1 + rng.below(4) as u32,
        smem_staging: rng.chance(0.5),
        fuse_epilogue: rng.chance(0.5),
        layout: *rng.pick(&[Layout::RowMajor, Layout::ColMajor, Layout::Tiled]),
        threads_per_block: 32 * (1 + rng.below(32) as u32),
        regs_per_thread: 16 + rng.below(240) as u32,
    }
}

fn arbitrary_spec(rng: &mut Rng) -> KernelSpec {
    let ops = ["matmul_64", "softmax_64", "x", "op_1", "a_very_long_kernel_name_0123"];
    let sems = ["opt", "ref", "bug_scale", "bug_offset", "weird_variant"];
    KernelSpec {
        op: rng.pick(&ops).to_string(),
        semantics: rng.pick(&sems).to_string(),
        schedule: arbitrary_schedule(rng),
    }
}

/// print ∘ parse = id over the whole AST space.
#[test]
fn prop_dsl_roundtrip() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let spec = arbitrary_spec(&mut rng);
        let text = dsl::print(&spec);
        let back = dsl::parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{text}"));
        assert_eq!(spec, back, "seed {seed}");
        // And printing is a fixpoint.
        assert_eq!(text, dsl::print(&back), "seed {seed}");
    }
}

/// The parser never panics and never accepts unbalanced braces, for
/// arbitrary mutations of valid programs.
#[test]
fn prop_parser_total_on_corruptions() {
    for seed in 0..CASES {
        let mut rng = Rng::new(1000 + seed);
        let spec = arbitrary_spec(&mut rng);
        let mut text = dsl::print(&spec);
        // Random byte-level corruption.
        for _ in 0..1 + rng.below(4) {
            if text.is_empty() {
                break;
            }
            let i = rng.below(text.len());
            if text.is_char_boundary(i) {
                let c = *rng.pick(&[b'{', b'}', b';', b':', b'q', b'7', b' ']) as char;
                text.insert(i, c);
            }
        }
        // Must not panic; outcome (Ok or Err) is free.
        let _ = dsl::parse(&text);
    }
}

/// Validation is decidable and consistent: validate(spec) agrees with
/// validate(parse(print(spec))).
#[test]
fn prop_validate_stable_under_roundtrip() {
    for seed in 0..CASES {
        let mut rng = Rng::new(2000 + seed);
        let spec = arbitrary_spec(&mut rng);
        let direct = dsl::validate(&spec).is_ok();
        let round = dsl::parse(&dsl::print(&spec)).map(|s| dsl::validate(&s).is_ok());
        assert_eq!(Ok(direct), round, "seed {seed}");
    }
}

fn arbitrary_candidate(rng: &mut Rng, trial: usize) -> Candidate {
    let valid = rng.chance(0.6);
    let speedup = if valid { 0.5 + 3.0 * rng.f64() } else { 1.0 };
    Candidate {
        src: format!("kernel k{} {{ semantics: opt; }}", rng.below(100_000)),
        spec: None,
        compiled: valid || rng.chance(0.5),
        correct: valid,
        speedup,
        pytorch_speedup: speedup * 0.7,
        true_speedup: speedup,
        true_pytorch_speedup: speedup * 0.7,
        insight: None,
        trial,
    }
}

/// Population invariants, for every strategy:
/// * `best()` is valid and has the max fitness ever inserted (among
///   valid candidates, when deduplication permits);
/// * `history()` is sorted best-first and contains only valid items;
/// * `parent()` never panics, returns something once nonempty.
#[test]
fn prop_population_invariants() {
    for seed in 0..200u64 {
        let mut rng = Rng::new(3000 + seed);
        let pops: Vec<Box<dyn Population>> = vec![
            Box::new(SingleBest::new()),
            Box::new(Elite::new(1 + rng.below(5))),
            Box::new(Islands::new(1 + rng.below(4), 1 + rng.below(3), 1 + rng.below(20))),
        ];
        for mut pop in pops {
            let mut max_valid_fitness: f64 = 0.0;
            for t in 0..40 {
                // Interleave selection like the real loop (islands
                // advance their cursor in parent()).
                let _ = pop.parent(&mut rng);
                let c = arbitrary_candidate(&mut rng, t);
                if c.valid() {
                    max_valid_fitness = max_valid_fitness.max(c.fitness());
                }
                pop.insert(c);

                if let Some(best) = pop.best() {
                    assert!(best.valid(), "{} seed {seed}", pop.name());
                    assert!(
                        best.fitness() <= max_valid_fitness + 1e-12,
                        "{} seed {seed}",
                        pop.name()
                    );
                }
                let hist = pop.history(4);
                for w in hist.windows(2) {
                    assert!(
                        w[0].fitness() >= w[1].fitness(),
                        "{} history not sorted, seed {seed}",
                        pop.name()
                    );
                }
                for h in &hist {
                    assert!(h.valid(), "{} history has invalid, seed {seed}", pop.name());
                }
                assert!(pop.parent(&mut rng).is_some(), "{} seed {seed}", pop.name());
            }
            // SingleBest/Elite: best is the global max over valid.
            if pop.name() != "islands" {
                if max_valid_fitness > 0.0 {
                    let b = pop.best().expect("valid inserted but no best");
                    assert!((b.fitness() - max_valid_fitness).abs() < 1e-12);
                }
            }
        }
    }
}

/// JSON writer/parser round-trip over arbitrary structured values.
#[test]
fn prop_json_roundtrip() {
    fn arbitrary_json(rng: &mut Rng, depth: usize) -> json::Json {
        use json::Json;
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => {
                // Round-trippable numbers (f64-exact).
                Json::Num((rng.next_u64() % 1_000_000) as f64 - 500_000.0)
            }
            3 => {
                let len = rng.below(12);
                let s: String = (0..len)
                    .map(|_| *rng.pick(&['a', 'Z', '"', '\\', '\n', '\t', '✓', ' ', '0']))
                    .collect();
                Json::Str(s)
            }
            4 => {
                let n = rng.below(4);
                Json::Arr((0..n).map(|_| arbitrary_json(rng, depth - 1)).collect())
            }
            _ => {
                let n = rng.below(4);
                Json::Obj(
                    (0..n)
                        .map(|i| (format!("k{i}"), arbitrary_json(rng, depth - 1)))
                        .collect(),
                )
            }
        }
    }
    for seed in 0..CASES {
        let mut rng = Rng::new(4000 + seed);
        let v = arbitrary_json(&mut rng, 3);
        let text = v.to_string();
        let back = json::parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{text}"));
        assert_eq!(v, back, "seed {seed}");
    }
}

/// Schedule resource accounting is monotone: growing a tile never
/// shrinks the shared-memory footprint or the register estimate.
#[test]
fn prop_resource_monotonicity() {
    for seed in 0..CASES {
        let mut rng = Rng::new(5000 + seed);
        let mut s = arbitrary_schedule(&mut rng);
        s.smem_staging = true;
        let smem0 = s.smem_bytes();
        let regs0 = s.est_registers();
        let mut bigger = s.clone();
        bigger.tile_m = (s.tile_m * 2).min(256);
        bigger.tile_n = (s.tile_n * 2).min(256);
        assert!(bigger.smem_bytes() >= smem0, "seed {seed}");
        assert!(bigger.est_registers() >= regs0, "seed {seed}");
    }
}
