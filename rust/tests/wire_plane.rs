//! Distributed work-plane conformance (DESIGN.md §15).
//!
//! The coordinator/worker contract under test: a `campaign serve`
//! coordinator plus N `campaign work` workers produces **byte-identical**
//! records, reports, and event journals to an uninterrupted in-process
//! `--concurrency 1` sweep — for N ∈ {1, 2}, and across a worker that
//! dies mid-cell (trial-gate kill), releases its cell, and has a second
//! worker re-claim and finish it at trial granularity.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use evoengineer::campaign::coordinator::Coordinator;
use evoengineer::campaign::wire::{self, WorkOpts};
use evoengineer::campaign::{self, CampaignConfig};
use evoengineer::evals::Evaluator;
use evoengineer::methods::KernelRunRecord;
use evoengineer::report;
use evoengineer::runtime::Runtime;
use evoengineer::store::EvalStore;
use evoengineer::tasks::TaskRegistry;

fn registry() -> Arc<TaskRegistry> {
    Arc::new(
        TaskRegistry::load(PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")).unwrap(),
    )
}

fn evaluator() -> Evaluator {
    Evaluator::new(registry(), Runtime::new().unwrap())
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "evo_wire_{tag}_{}_{}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .subsec_nanos()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Two cells (2 methods × 1 model × 1 op × 1 seed), 4 trials each —
/// the cheapest grid that exercises claim ordering, a mid-cell kill
/// (the gate at 6 trips inside cell 2), and cross-cell merge.
fn base_cfg() -> CampaignConfig {
    CampaignConfig {
        methods: vec!["evoengineer-free".into(), "funsearch".into()],
        models: vec!["gpt".into()],
        seeds: vec![0],
        op_filter: "relu_64".into(),
        budget: 4,
        quiet: true,
        concurrency: 1,
        ..CampaignConfig::default()
    }
}

/// The golden reference: an uninterrupted in-process `--concurrency 1`
/// sweep, with its event-journal bytes.
fn reference(dir: &Path) -> (Vec<KernelRunRecord>, Vec<u8>) {
    let events = dir.join("ref_events.jsonl");
    let cfg = CampaignConfig { events: Some(events.clone()), ..base_cfg() };
    let records = campaign::run(&cfg, evaluator()).unwrap();
    assert_eq!(records.len(), 2);
    (records, std::fs::read(&events).unwrap())
}

fn assert_records_identical(reference: &[KernelRunRecord], got: &[KernelRunRecord]) {
    assert_eq!(reference.len(), got.len());
    for (a, b) in reference.iter().zip(got) {
        assert_eq!(
            a.to_json().to_string(),
            b.to_json().to_string(),
            "distributed record diverged for {}/{}",
            a.method,
            a.op
        );
    }
}

#[test]
fn coordinator_plus_n_workers_matches_the_inprocess_sweep() {
    let dir = tmpdir("n_workers");
    let (full, ref_events) = reference(&dir);

    for n_workers in [1usize, 2] {
        let events = dir.join(format!("events_{n_workers}.jsonl"));
        let cfg = CampaignConfig {
            events: Some(events.clone()),
            checkpoint: Some(dir.join(format!("ckpt_{n_workers}.jsonl"))),
            ..base_cfg()
        };
        let merged_cache = dir.join(format!("merged_cache_{n_workers}.jsonl"));
        let coord =
            Coordinator::start(&cfg, &registry(), "127.0.0.1:0", Some(&merged_cache)).unwrap();
        let url = coord.url();

        let summaries: Vec<wire::WorkSummary> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..n_workers)
                .map(|_| {
                    let url = url.clone();
                    scope.spawn(move || {
                        let opts = WorkOpts { concurrency: 1, quiet: true, ..WorkOpts::default() };
                        wire::work(&url, evaluator(), &opts).unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let (records, stats) = coord.wait().unwrap();

        assert_records_identical(&full, &records);
        assert_eq!(
            std::fs::read(&events).unwrap(),
            ref_events,
            "{n_workers}-worker event journal is not byte-identical to the reference"
        );
        assert_eq!(report::table4(&full), report::table4(&records));
        assert_eq!(report::tokens(&full), report::tokens(&records));

        let completed: usize = summaries.iter().map(|s| s.cells_completed).sum();
        assert_eq!(completed, 2, "every cell completed by exactly one worker");
        assert!(summaries.iter().all(|s| !s.interrupted));
        assert_eq!(stats.grid, 2);
        assert_eq!(stats.claims, 2);
        assert_eq!(stats.completions, 2);
        assert_eq!(stats.reclaims, 0);
        assert_eq!(stats.duplicate_completions, 0);
        assert!(stats.events > 0, "trial events were streamed, not lost");
    }
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn warm_started_wire_sweep_matches_the_inprocess_sweep() {
    use evoengineer::util::httpwire::{request_json, split_url};
    use std::time::Duration;

    let dir = tmpdir("warm");

    // Fill a bank from a cold in-process pass of the same slice.
    let bank = dir.join("bank.jsonl");
    let seed_cfg = CampaignConfig { bank: Some(bank.clone()), ..base_cfg() };
    campaign::run(&seed_cfg, evaluator()).unwrap();
    assert!(evoengineer::bank::stats(&bank).unwrap().entries > 0, "cold pass deposited nothing");

    // Golden reference: the warm-started single-process sweep.
    let ref_events = dir.join("ref_events.jsonl");
    let ref_cfg = CampaignConfig {
        warm_start: Some(bank.clone()),
        events: Some(ref_events.clone()),
        ..base_cfg()
    };
    let full = campaign::run(&ref_cfg, evaluator()).unwrap();

    // Distributed: the coordinator loads the snapshot once and ships
    // it to both workers over GET /bank; neither worker touches the
    // bank file.
    let events = dir.join("events.jsonl");
    let cfg = CampaignConfig {
        warm_start: Some(bank.clone()),
        events: Some(events.clone()),
        checkpoint: Some(dir.join("ckpt.jsonl")),
        ..base_cfg()
    };
    let coord = Coordinator::start(&cfg, &registry(), "127.0.0.1:0", None).unwrap();
    let url = coord.url();

    // /config advertises the snapshot; /bank serves its canonical
    // lines (what `from_lines` rebuilds worker-side).
    let base = split_url(&url).unwrap();
    let (code, cfg_text) = request_json(&base, "GET", "/config", "", Duration::from_secs(5)).unwrap();
    assert_eq!(code, 200);
    assert!(cfg_text.contains("\"warm_start\":true"), "{cfg_text}");
    let (code, bank_text) = request_json(&base, "GET", "/bank", "", Duration::from_secs(5)).unwrap();
    assert_eq!(code, 200);
    assert!(bank_text.contains("\"lines\""), "{bank_text}");

    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let url = url.clone();
                scope.spawn(move || {
                    let opts = WorkOpts { concurrency: 1, quiet: true, ..WorkOpts::default() };
                    wire::work(&url, evaluator(), &opts).unwrap()
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
    let (records, _) = coord.wait().unwrap();

    assert_records_identical(&full, &records);
    assert_eq!(
        std::fs::read(&events).unwrap(),
        std::fs::read(&ref_events).unwrap(),
        "warm-started 2-worker event journal is not byte-identical to the reference"
    );
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn coordinator_serves_prometheus_metrics() {
    use evoengineer::util::httpwire::{request_json, split_url};
    use std::time::Duration;

    let dir = tmpdir("metrics");
    let cfg = CampaignConfig {
        checkpoint: Some(dir.join("ckpt.jsonl")),
        ..base_cfg()
    };
    let coord = Coordinator::start(&cfg, &registry(), "127.0.0.1:0", None).unwrap();
    let url = coord.url();
    let base = split_url(&url).unwrap();
    let timeout = Duration::from_secs(5);

    // Pre-sweep scrape: text exposition format, grid visible, nothing
    // done yet.
    let (code, text) = request_json(&base, "GET", "/metrics", "", timeout).unwrap();
    assert_eq!(code, 200);
    assert!(text.contains("# TYPE campaign_uptime_seconds gauge"), "{text}");
    assert!(text.contains("campaign_grid_cells 2\n"), "{text}");
    assert!(text.contains("campaign_cells_done 0\n"), "{text}");
    assert!(text.contains("campaign_trials_per_second"), "{text}");

    // /config carries the goal knob workers mirror (default sweep).
    let (code, cfg_text) = request_json(&base, "GET", "/config", "", timeout).unwrap();
    assert_eq!(code, 200);
    assert!(cfg_text.contains("\"goal\":\"speedup\""), "{cfg_text}");

    // Drain the grid with one worker, then scrape again.
    let opts = WorkOpts { concurrency: 1, quiet: true, ..WorkOpts::default() };
    wire::work(&url, evaluator(), &opts).unwrap();
    let (code, text) = request_json(&base, "GET", "/metrics", "", timeout).unwrap();
    assert_eq!(code, 200);
    assert!(text.contains("campaign_cells_done 2\n"), "{text}");
    assert!(text.contains("campaign_completions_total 2\n"), "{text}");
    assert!(text.contains("evo_runs_finished_total 2\n"), "{text}");
    // 2 cells x 4-trial budget folded from the event buffers.
    assert!(text.contains("evo_trial_groups_total 8\n"), "{text}");
    assert!(text.contains("evo_prompt_tokens_total"), "{text}");
    // Labeled families: per-outcome trials and per-goal completions.
    assert!(text.contains("evo_trials_total{outcome="), "{text}");
    assert!(text.contains("campaign_goal_runs_total{goal=\"speedup\"} 2\n"), "{text}");
    assert!(text.contains("campaign_goal_valid_runs_total{goal=\"speedup\"}"), "{text}");

    let (records, _) = coord.wait().unwrap();
    assert_eq!(records.len(), 2);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn worker_death_mid_cell_reclaims_to_byte_identical_results() {
    let dir = tmpdir("kill");
    let (full, ref_events) = reference(&dir);

    let events = dir.join("events.jsonl");
    let cfg = CampaignConfig {
        events: Some(events.clone()),
        checkpoint: Some(dir.join("ckpt.jsonl")),
        // The coordinator's merged transcript journal: worker 1's
        // uploaded provider calls warm worker 2's replay of the
        // re-claimed cell.
        transcripts: Some(dir.join("merged_transcripts.jsonl")),
        ..base_cfg()
    };
    let merged_cache = dir.join("merged_cache.jsonl");
    let coord =
        Coordinator::start(&cfg, &registry(), "127.0.0.1:0", Some(&merged_cache)).unwrap();
    let url = coord.url();

    // Worker 1 dies mid-cell: the gate trips after 6 trial groups —
    // cell 1 takes 4, so cell 2 is released with exactly 2 trials
    // complete and streamed to the coordinator.
    let w1 = WorkOpts {
        concurrency: 1,
        quiet: true,
        stop_after_trials: 6,
        transcripts: Some(dir.join("w1_transcripts.jsonl")),
        cache: Some(dir.join("w1_cache.jsonl")),
        ..WorkOpts::default()
    };
    let s1 = wire::work(
        &url,
        evaluator().with_store(EvalStore::open(dir.join("w1_cache.jsonl")).unwrap()),
        &w1,
    )
    .unwrap();
    assert!(s1.interrupted, "the trial gate tripped");
    assert_eq!(s1.cells_completed, 1, "cell 2 was killed mid-run");

    // Worker 2 (a fresh process-equivalent: its own evaluator, cache,
    // transcript journal) re-claims the released cell at epoch 1,
    // replays the dead worker's 2 completed trials warm from the
    // coordinator-merged transcripts, and finishes live.
    let w2 = WorkOpts {
        concurrency: 1,
        quiet: true,
        transcripts: Some(dir.join("w2_transcripts.jsonl")),
        cache: Some(dir.join("w2_cache.jsonl")),
        ..WorkOpts::default()
    };
    let s2 = wire::work(
        &url,
        evaluator().with_store(EvalStore::open(dir.join("w2_cache.jsonl")).unwrap()),
        &w2,
    )
    .unwrap();
    assert!(!s2.interrupted);
    assert_eq!(s2.cells_completed, 1, "exactly the re-claimed cell");

    let (records, stats) = coord.wait().unwrap();
    assert_records_identical(&full, &records);
    assert_eq!(
        std::fs::read(&events).unwrap(),
        ref_events,
        "event journal across the kill is not byte-identical to the reference"
    );
    assert_eq!(report::table4(&full), report::table4(&records));

    assert_eq!(stats.reclaims, 1, "the killed cell was re-offered once");
    assert_eq!(stats.claims, 3, "2 cells + 1 re-claim");
    assert_eq!(stats.completions, 2);
    assert!(stats.transcript_lines_merged > 0, "worker uploads reached the merged journal");
    assert!(stats.eval_lines_merged > 0);

    // The merged stores are valid journals, not interleaved garbage.
    let merged = EvalStore::open(&merged_cache).unwrap();
    assert!(merged.len() > 0);

    std::fs::remove_dir_all(dir).ok();
}
