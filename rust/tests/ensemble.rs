//! Ensemble/bandit conformance suite (DESIGN.md §16).
//!
//! Three contracts layered on top of the provider-seam suite
//! (`provider_conformance.rs`):
//!
//! 1. **Degenerate identity.** A single-member ensemble at weight 1.0
//!    is byte-identical to the bare backend — same records, same
//!    transcript journal, same reports. The ensemble machinery must be
//!    invisible until there are actually two members to arbitrate.
//! 2. **Record-then-replay.** A multi-member sim ensemble campaign
//!    recorded once replays byte-identically with zero live
//!    generation: the bandit re-derives every routing decision from
//!    the seeds, so the replayed request hashes land on the journal.
//! 3. **Determinism.** Same-seed reruns and `--prefetch` on/off yield
//!    byte-identical records, learned arm weights included — bandit
//!    updates happen only at sequential trial-finish time, so
//!    speculation can cost hash-misses but never perturb results.

use std::path::PathBuf;
use std::sync::Arc;

use evoengineer::campaign::{self, CampaignConfig};
use evoengineer::evals::Evaluator;
use evoengineer::llm::ProviderSpec;
use evoengineer::methods::RepairPolicy;
use evoengineer::report;
use evoengineer::runtime::Runtime;
use evoengineer::tasks::TaskRegistry;

fn evaluator() -> Evaluator {
    let reg = Arc::new(
        TaskRegistry::load(PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")).unwrap(),
    );
    Evaluator::new(reg, Runtime::new().unwrap())
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "evo_ensemble_{tag}_{}_{}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .subsec_nanos()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn record_lines(records: &[evoengineer::methods::KernelRunRecord]) -> Vec<String> {
    records.iter().map(|r| r.to_json().to_string()).collect()
}

#[test]
fn single_member_ensemble_is_byte_identical_to_the_bare_backend() {
    let dir = tmpdir("degenerate");
    let base = CampaignConfig {
        methods: vec!["evoengineer-free".into()],
        models: vec!["gpt".into()],
        seeds: vec![0, 1],
        op_filter: "relu_64".into(),
        budget: 6,
        repair: RepairPolicy::Repair { max_attempts: 2 },
        quiet: true,
        ..CampaignConfig::default()
    };

    let bare_journal = dir.join("bare.jsonl");
    let bare = campaign::run(
        &CampaignConfig {
            provider: ProviderSpec::Sim,
            transcripts: Some(bare_journal.clone()),
            ..base.clone()
        },
        evaluator(),
    )
    .unwrap();

    let ens_journal = dir.join("ensemble.jsonl");
    let ens = campaign::run(
        &CampaignConfig {
            provider: ProviderSpec::parse("ensemble:[sim@1.0]").unwrap(),
            transcripts: Some(ens_journal.clone()),
            ..base.clone()
        },
        evaluator(),
    )
    .unwrap();

    assert!(!bare.is_empty());
    assert_eq!(record_lines(&bare), record_lines(&ens));
    // The degenerate ensemble never routes: label collapses to the
    // member's own, no bandit, no arms, no route lines.
    assert!(ens.iter().all(|r| r.provider == "sim"));
    assert!(ens.iter().all(|r| r.arms.is_empty()));
    assert_eq!(
        std::fs::read(&bare_journal).unwrap(),
        std::fs::read(&ens_journal).unwrap(),
        "transcript journals must match byte-for-byte"
    );
    assert_eq!(report::table4(&bare), report::table4(&ens));
    assert_eq!(report::tokens(&bare), report::tokens(&ens));
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn ensemble_record_then_replay_is_bit_identical_with_zero_live_generation() {
    let dir = tmpdir("replay");
    let transcripts = dir.join("transcripts.jsonl");
    // Category-6 ops + repair policy: both roles (generate and repair)
    // route through the bandit and flow through the journal.
    let base = CampaignConfig {
        methods: vec!["evoengineer-free".into()],
        models: vec!["gpt".into()],
        seeds: vec![0, 1],
        op_filter: "cum".into(),
        budget: 8,
        repair: RepairPolicy::Repair { max_attempts: 2 },
        quiet: true,
        ..CampaignConfig::default()
    };

    let spec = ProviderSpec::parse("ensemble:[sim@0.5,sim#alt@0.5]").unwrap();
    let recorded = campaign::run(
        &CampaignConfig {
            provider: spec.clone(),
            transcripts: Some(transcripts.clone()),
            ..base.clone()
        },
        evaluator(),
    )
    .unwrap();
    assert!(!recorded.is_empty());
    // Records carry the canonical ensemble label and learned arms.
    let label = spec.label();
    assert_eq!(label, "ensemble:[sim@0.5,sim#alt@0.5,x=0.25]");
    assert!(recorded.iter().all(|r| r.provider == label));
    assert!(
        recorded.iter().all(|r| !r.arms.is_empty()),
        "multi-member runs must record learned arm weights"
    );
    assert!(
        recorded.iter().any(|r| r.repair_attempts > 0),
        "repair calls must flow through the bandit for this test to bite"
    );
    let journal_bytes = std::fs::read(&transcripts).unwrap();
    let journal_text = String::from_utf8(journal_bytes.clone()).unwrap();
    assert!(
        journal_text.contains("\"type\":\"route\""),
        "multi-member recording must journal routing decisions"
    );

    // Replay: the ReplayProvider has no live backend by construction,
    // so a successful identical run proves zero live generation. The
    // bandit re-derives every route from the impersonated label.
    let replayed = campaign::run(
        &CampaignConfig {
            provider: ProviderSpec::Replay(transcripts.clone()),
            transcripts: None,
            ..base.clone()
        },
        evaluator(),
    )
    .unwrap();
    assert_eq!(record_lines(&recorded), record_lines(&replayed));
    assert_eq!(report::table4(&recorded), report::table4(&replayed));
    assert_eq!(report::tokens(&recorded), report::tokens(&replayed));
    assert!(report::tokens(&replayed).contains("ARM WEIGHTS"));
    assert_eq!(
        journal_bytes,
        std::fs::read(&transcripts).unwrap(),
        "replay must not append to the transcript journal"
    );
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn bandit_selection_is_stable_across_reruns_and_prefetch() {
    let base = CampaignConfig {
        methods: vec!["evoengineer-free".into(), "eoh".into()],
        models: vec!["claude".into()],
        seeds: vec![0],
        op_filter: "softmax_64".into(),
        budget: 6,
        repair: RepairPolicy::Repair { max_attempts: 1 },
        quiet: true,
        provider: ProviderSpec::parse("ensemble:[sim@0.7,sim#alt@0.3,x=0.4]").unwrap(),
        ..CampaignConfig::default()
    };
    let a = campaign::run(&base, evaluator()).unwrap();
    let b = campaign::run(&base, evaluator()).unwrap();
    assert_eq!(record_lines(&a), record_lines(&b), "same-seed reruns must agree");
    assert!(a.iter().all(|r| !r.arms.is_empty()));

    // Speculative prefetch may waste stamped routes (hash misses) but
    // must never change which member a committed trial used, nor the
    // learned weights: updates happen only at sequential finish time.
    let prefetched = campaign::run(
        &CampaignConfig { prefetch: 3, ..base.clone() },
        evaluator(),
    )
    .unwrap();
    assert_eq!(
        record_lines(&a),
        record_lines(&prefetched),
        "prefetch must not perturb bandit selection or arm weights"
    );
}
