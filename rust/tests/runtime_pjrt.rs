//! PJRT runtime tests: load the AOT HLO-text artifacts and execute them
//! with concrete inputs — the rust mirror of python/tests/test_aot.py.
//! These are the tests that prove the L2→L3 AOT bridge (jax lowering →
//! HLO text → xla crate → PJRT CPU) carries real numerics.

use std::path::PathBuf;
use std::sync::Arc;

use evoengineer::runtime::{Runtime, TensorValue};
use evoengineer::tasks::gen::gen_case;
use evoengineer::tasks::TaskRegistry;

fn registry() -> TaskRegistry {
    TaskRegistry::load(PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")).unwrap()
}

fn inputs_for(reg: &TaskRegistry, op: &str, case: usize) -> Vec<TensorValue> {
    let task = reg.get(op).unwrap();
    gen_case(task, case)
        .into_iter()
        .zip(&task.args)
        .map(|(data, spec)| TensorValue::new(spec.shape.clone(), data))
        .collect()
}

#[test]
fn executes_matmul_with_known_numerics() {
    let reg = registry();
    let rt = Runtime::new().unwrap();
    let task = reg.get("matmul_32").unwrap();
    // Identity x random == random.
    let n = 32;
    let mut eye = vec![0.0f32; n * n];
    for i in 0..n {
        eye[i * n + i] = 1.0;
    }
    let x: Vec<f32> = (0..n * n).map(|i| (i % 17) as f32 * 0.25 - 2.0).collect();
    let out = rt
        .execute(
            reg.artifact_path(task, "ref").unwrap(),
            vec![
                TensorValue::new(vec![n, n], eye),
                TensorValue::new(vec![n, n], x.clone()),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), n * n);
    for (a, b) in out.iter().zip(&x) {
        assert!((a - b).abs() < 1e-5, "{a} vs {b}");
    }
}

#[test]
fn opt_matches_ref_live_for_sampled_ops() {
    // The rust-side half of the kernel-vs-oracle check: execute both
    // artifacts on PJRT and compare, one op per category.
    let reg = registry();
    let rt = Runtime::new().unwrap();
    for op_name in [
        "matmul_rect_64x32x128",
        "conv2d_k3_c8",
        "silu_big",
        "layernorm_64",
        "kl_div_64",
        "cumprod_rows_64",
    ] {
        let task = reg.get(op_name).unwrap();
        for case in 0..2 {
            let inputs = inputs_for(&reg, op_name, case);
            let want = rt
                .execute(reg.artifact_path(task, "ref").unwrap(), inputs.clone())
                .unwrap();
            let got = rt
                .execute(reg.artifact_path(task, "opt").unwrap(), inputs)
                .unwrap();
            assert_eq!(want.len(), got.len(), "{op_name}");
            for (w, g) in want.iter().zip(&got) {
                assert!(
                    (w - g).abs() as f64 <= task.atol + task.rtol * w.abs() as f64,
                    "{op_name} case {case}: {w} vs {g}"
                );
            }
        }
    }
}

#[test]
fn bug_artifacts_differ_live() {
    let reg = registry();
    let rt = Runtime::new().unwrap();
    let task = reg.get("softmax_256").unwrap();
    let inputs = inputs_for(&reg, "softmax_256", 0);
    let want = rt
        .execute(reg.artifact_path(task, "ref").unwrap(), inputs.clone())
        .unwrap();
    for bug in ["bug_scale", "bug_offset"] {
        let got = rt
            .execute(reg.artifact_path(task, bug).unwrap(), inputs.clone())
            .unwrap();
        let max_diff = want
            .iter()
            .zip(&got)
            .map(|(w, g)| (w - g).abs())
            .fold(0.0f32, f32::max);
        assert!(
            (max_diff as f64) > task.atol,
            "{bug} indistinguishable (max diff {max_diff})"
        );
    }
}

#[test]
fn output_shapes_match_manifest() {
    let reg = registry();
    let rt = Runtime::new().unwrap();
    // Mixed-rank sample: 2D, 3D, 4D outputs and scalar-ish (1,1).
    for op_name in ["bmm_4x64", "avgpool1d_k2", "instancenorm_8", "hinge_64", "maxpool2d_k4"] {
        let task = reg.get(op_name).unwrap();
        let inputs = inputs_for(&reg, op_name, 3);
        let out = rt
            .execute(reg.artifact_path(task, "ref").unwrap(), inputs)
            .unwrap();
        assert_eq!(out.len(), task.out_numel(), "{op_name}");
        assert!(out.iter().all(|x| x.is_finite()), "{op_name} non-finite output");
    }
}

#[test]
fn executable_cache_reuses_compilations() {
    let reg = registry();
    let rt = Runtime::new().unwrap();
    let task = reg.get("relu_64").unwrap();
    let path = reg.artifact_path(task, "ref").unwrap();
    for case in 0..4 {
        let inputs = inputs_for(&reg, "relu_64", case);
        rt.execute(path.clone(), inputs).unwrap();
    }
    let stats = rt.stats().unwrap();
    assert_eq!(stats.compiles, 1, "{stats:?}");
    assert_eq!(stats.executions, 4, "{stats:?}");
    assert_eq!(stats.cache_hits, 3, "{stats:?}");
}

#[test]
fn runtime_is_shareable_across_threads() {
    let reg = Arc::new(registry());
    let rt = Runtime::new().unwrap();
    let mut handles = Vec::new();
    for t in 0..4 {
        let reg = reg.clone();
        let rt = rt.clone();
        handles.push(std::thread::spawn(move || {
            let task = reg.get("tanh_64").unwrap();
            let inputs = gen_case(task, t)
                .into_iter()
                .zip(&task.args)
                .map(|(data, spec)| TensorValue::new(spec.shape.clone(), data))
                .collect();
            let out = rt
                .execute(reg.artifact_path(task, "opt").unwrap(), inputs)
                .unwrap();
            assert_eq!(out.len(), task.out_numel());
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn concurrent_execution_across_shard_pool() {
    // >= 4 worker threads against a >= 2-shard pool: every result must
    // match what a single-shard runtime computes for the same inputs.
    let reg = Arc::new(registry());
    let ops = ["tanh_64", "relu_64", "sigmoid_64", "softmax_256"];
    let single = Runtime::with_shards(1).unwrap();
    let mut expected = Vec::new();
    for (t, op) in ops.iter().enumerate() {
        let task = reg.get(op).unwrap();
        let out = single
            .execute(reg.artifact_path(task, "opt").unwrap(), inputs_for(&reg, op, t))
            .unwrap();
        expected.push(out);
    }
    let rt = Runtime::with_shards(4).unwrap();
    assert_eq!(rt.shard_count(), 4);
    let mut handles = Vec::new();
    for (t, op) in ops.iter().enumerate() {
        let reg = reg.clone();
        let rt = rt.clone();
        let op = op.to_string();
        handles.push(std::thread::spawn(move || {
            let task = reg.get(&op).unwrap();
            rt.execute(reg.artifact_path(task, "opt").unwrap(), inputs_for(&reg, &op, t))
                .unwrap()
        }));
    }
    for (h, want) in handles.into_iter().zip(&expected) {
        let got = h.join().unwrap();
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(want) {
            assert!((g - w).abs() < 1e-6, "{g} vs {w}");
        }
    }
}

#[test]
fn per_shard_compile_once_and_stats_aggregate() {
    let reg = registry();
    let rt = Runtime::with_shards(2).unwrap();
    let paths: Vec<_> = ["relu_64", "tanh_64", "sigmoid_64"]
        .iter()
        .map(|op| reg.artifact_path(reg.get(op).unwrap(), "ref").unwrap())
        .collect();
    // Two passes over three distinct artifacts: each compiles exactly
    // once in the whole pool (stable routing pins it to one shard),
    // the second pass is all cache hits.
    for pass in 0..2 {
        for (i, path) in paths.iter().enumerate() {
            let op = ["relu_64", "tanh_64", "sigmoid_64"][i];
            rt.execute(path.clone(), inputs_for(&reg, op, pass)).unwrap();
        }
    }
    let total = rt.stats().unwrap();
    assert_eq!(total.compiles, 3, "{total:?}");
    assert_eq!(total.executions, 6, "{total:?}");
    assert_eq!(total.cache_hits, 3, "{total:?}");
    // The aggregate is exactly the sum of the per-shard counters.
    let per_shard = rt.shard_stats().unwrap();
    assert_eq!(per_shard.len(), 2);
    assert_eq!(per_shard.iter().map(|s| s.compiles).sum::<u64>(), total.compiles);
    assert_eq!(per_shard.iter().map(|s| s.executions).sum::<u64>(), total.executions);
    assert_eq!(per_shard.iter().map(|s| s.cache_hits).sum::<u64>(), total.cache_hits);
    // No shard compiled an artifact that routes elsewhere.
    for (shard, s) in per_shard.iter().enumerate() {
        let routed_here = paths.iter().filter(|p| rt.shard_of(p) == shard).count() as u64;
        assert_eq!(s.compiles, routed_here, "shard {shard}: {s:?}");
    }
}

#[test]
fn shard_routing_is_stable() {
    let reg = registry();
    let task = reg.get("matmul_32").unwrap();
    let path = reg.artifact_path(task, "ref").unwrap();
    let a = Runtime::with_shards(3).unwrap();
    let b = Runtime::with_shards(3).unwrap();
    let first = a.shard_of(&path);
    assert!(first < 3);
    // Same path -> same shard: across repeated calls and across
    // independent runtime instances with the same shard count.
    for _ in 0..10 {
        assert_eq!(a.shard_of(&path), first);
    }
    assert_eq!(b.shard_of(&path), first);
}

#[test]
fn execute_pairs_matches_sequential_execution() {
    let reg = registry();
    let rt = Runtime::with_shards(2).unwrap();
    let task = reg.get("layernorm_64").unwrap();
    let ref_path = reg.artifact_path(task, "ref").unwrap();
    let opt_path = reg.artifact_path(task, "opt").unwrap();
    let cases: Arc<Vec<Vec<TensorValue>>> =
        Arc::new((0..5).map(|c| inputs_for(&reg, "layernorm_64", c)).collect());
    let (wants, gots) = rt.execute_pairs(ref_path.clone(), opt_path.clone(), cases).unwrap();
    assert_eq!(wants.len(), 5);
    assert_eq!(gots.len(), 5);
    for c in 0..5 {
        let seq_want = rt.execute(ref_path.clone(), inputs_for(&reg, "layernorm_64", c)).unwrap();
        let seq_got = rt.execute(opt_path.clone(), inputs_for(&reg, "layernorm_64", c)).unwrap();
        assert_eq!(wants[c], seq_want, "case {c}");
        assert_eq!(gots[c], seq_got, "case {c}");
    }
}

#[test]
fn batched_execution_counts_cases_and_resolves_executables_once() {
    let reg = registry();
    let rt = Runtime::with_shards(1).unwrap();
    let task = reg.get("silu_big").unwrap();
    let ref_path = reg.artifact_path(task, "ref").unwrap();
    let opt_path = reg.artifact_path(task, "opt").unwrap();
    let cases: Arc<Vec<Vec<TensorValue>>> =
        Arc::new((0..5).map(|c| inputs_for(&reg, "silu_big", c)).collect());
    rt.execute_pairs(ref_path.clone(), opt_path.clone(), cases.clone()).unwrap();
    let stats = rt.stats().unwrap();
    // 5 cases x 2 artifacts = 10 executions, but only 2 compiles and no
    // cache churn: a batch resolves its executable once per request.
    assert_eq!(stats.executions, 10, "{stats:?}");
    assert_eq!(stats.compiles, 2, "{stats:?}");
    assert_eq!(stats.cache_hits, 0, "{stats:?}");
    // A second identical batch: two cache hits (one per artifact).
    rt.execute_pairs(ref_path, opt_path, cases).unwrap();
    let stats = rt.stats().unwrap();
    assert_eq!(stats.executions, 20, "{stats:?}");
    assert_eq!(stats.compiles, 2, "{stats:?}");
    assert_eq!(stats.cache_hits, 2, "{stats:?}");
}

#[test]
fn missing_artifact_is_an_error_not_a_panic() {
    let rt = Runtime::new().unwrap();
    let err = rt.execute(PathBuf::from("/nonexistent/x.hlo.txt"), vec![]);
    assert!(err.is_err());
    // The owner thread must survive the failure.
    let reg = registry();
    let task = reg.get("relu_64").unwrap();
    let inputs = inputs_for(&reg, "relu_64", 0);
    rt.execute(reg.artifact_path(task, "ref").unwrap(), inputs).unwrap();
}
