//! Parallel stage-0 guard determinism (DESIGN.md §14): screening a
//! candidate batch with `guard::check_batch` must be a pure
//! parallelization — identical verdicts, identical diagnostic
//! ordering, and byte-identical journaled GuardReject records — at
//! every worker count, over every baseline op in the manifest.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use evoengineer::costmodel::baseline_schedule;
use evoengineer::dsl::{self, KernelSpec};
use evoengineer::evals::{EvalOutcome, Evaluator};
use evoengineer::guard::{self, GuardReport};
use evoengineer::runtime::Runtime;
use evoengineer::store::{EvalStore, IndexMode};
use evoengineer::tasks::{OpTask, TaskRegistry};
use evoengineer::util::Rng;

fn registry() -> Arc<TaskRegistry> {
    Arc::new(
        TaskRegistry::load(PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")).unwrap(),
    )
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("evo_guardpar_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn baseline(op: &OpTask) -> String {
    dsl::print(&KernelSpec {
        op: op.name.clone(),
        semantics: "opt".into(),
        schedule: baseline_schedule(op),
    })
}

/// Every baseline op (all 91), each with an invalid companion drawn
/// from the candidate taxonomy, screened at worker counts 0 (auto),
/// 1 (the sequential path), and 2/4/8 (the pool). The batch result
/// must equal the one-by-one sequential reference exactly — same
/// verdicts, same diagnostics, same order.
#[test]
fn check_batch_matches_sequential_over_all_baseline_ops() {
    let reg = registry();
    let mut sources: Vec<(String, &OpTask)> = Vec::new();
    for (i, op) in reg.ops.iter().enumerate() {
        let base = baseline(op);
        sources.push((base.clone(), op));
        match i % 3 {
            // Syntax: not a program.
            0 => sources.push((base.replacen(';', " ", 1), op)),
            // Undefined ref: another op's baseline against this task.
            1 => {
                let other = &reg.ops[(i + 7) % reg.ops.len()];
                sources.push((baseline(other), op));
            }
            // Undefined ref: hallucinated semantics variant.
            _ => {
                let spec = KernelSpec {
                    op: op.name.clone(),
                    semantics: "turbo_v9".into(),
                    schedule: baseline_schedule(op),
                };
                sources.push((dsl::print(&spec), op));
            }
        }
    }
    let items: Vec<(&str, &OpTask)> = sources.iter().map(|(s, op)| (s.as_str(), *op)).collect();
    let reference: Vec<GuardReport> =
        items.iter().map(|(src, op)| guard::check_source(src, op)).collect();
    assert!(reference.iter().any(|r| r.pass()), "batch must contain passing candidates");
    assert!(reference.iter().any(|r| !r.pass()), "batch must contain rejected candidates");

    for workers in [0usize, 1, 2, 4, 8] {
        let got = guard::check_batch(&items, workers);
        assert_eq!(
            got, reference,
            "worker count {workers} changed a verdict, a diagnostic, or the ordering"
        );
    }
    assert!(guard::check_batch(&[], 4).is_empty(), "empty batch");
}

/// Journal identity: screen a guard-rejected batch in parallel, then
/// journal the rejections (sequentially, in batch order — exactly what
/// the engine does at trial boundaries). Two independent runs must
/// produce byte-identical journal files: parallel screening must not
/// perturb the journaled GuardReject keys, record contents, or order.
#[test]
fn parallel_screening_journals_byte_identical_rejections() {
    let reg = registry();
    let dir = tmpdir("journal");
    let cands: Vec<(String, OpTask)> = ["matmul_64", "relu_64", "softmax_256", "layernorm_64",
        "tanh_64"]
        .iter()
        .map(|&name| {
            let op = reg.get(name).expect(name).clone();
            let mut spec = KernelSpec {
                op: op.name.clone(),
                semantics: "opt".into(),
                schedule: baseline_schedule(&op),
            };
            spec.schedule.tile_k = 0; // compile-legal, guard-rejected
            (dsl::print(&spec), op)
        })
        .collect();

    let run = |path: &Path| {
        let ev = Evaluator::new(reg.clone(), Runtime::new().unwrap())
            .with_store(EvalStore::open_with(path, IndexMode::Auto).unwrap());
        let items: Vec<(&str, &OpTask)> = cands.iter().map(|(s, op)| (s.as_str(), op)).collect();
        let reports = guard::check_batch(&items, 4);
        for ((src, op), report) in cands.iter().zip(&reports) {
            assert!(!report.pass(), "{}: mutant unexpectedly passed the guard", op.name);
            let mut rng = Rng::new(9);
            let out = ev.evaluate_guarded(src, op, "-", &mut rng);
            assert!(matches!(out, EvalOutcome::GuardReject { .. }), "{}: {out:?}", op.name);
        }
        assert_eq!(ev.runtime_stats().unwrap().executions, 0, "rejects must never hit PJRT");
        ev.store().unwrap().flush().unwrap();
        std::fs::read(path).unwrap()
    };
    let a = run(&dir.join("a.jsonl"));
    let b = run(&dir.join("b.jsonl"));
    assert!(!a.is_empty());
    assert_eq!(a, b, "journaled GuardReject records diverged across identical runs");
    std::fs::remove_dir_all(&dir).ok();
}
