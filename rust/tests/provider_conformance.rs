//! Provider-seam conformance suite (DESIGN.md §12).
//!
//! Three contracts, exercised against every in-tree backend:
//!
//! 1. **Sim identity (the golden-record proof).** `Session::trial`
//!    derives its per-call seed with the exact arithmetic the
//!    pre-provider code used to derive its per-call `Rng`
//!    (`Rng::derive(label)` ≡ `Rng::new(derive_seed(label))`, proven
//!    in `util::rng` tests), and `SimProvider` expands that seed with
//!    `Rng::new`. This file proves the remaining link: for any seed,
//!    the provider's output is byte-identical to the legacy free
//!    functions. Composed, `--provider sim` runs are byte-identical to
//!    pre-redesign runs — same emissions, same token accounting, same
//!    canonical texts, hence the same eval-cache keys.
//! 2. **Transcript record/replay.** Recording is transparent; replay
//!    serves byte-identical responses with *no* fallback backend, so a
//!    successful replay performed zero live generation, and a request
//!    outside the journal is a hard error.
//! 3. **Campaign-level identity.** A campaign recorded under sim and
//!    re-run under replay yields byte-identical records and reports.

use std::path::PathBuf;
use std::sync::Arc;

use evoengineer::campaign::{self, CampaignConfig};
use evoengineer::evals::Evaluator;
use evoengineer::guard::{GuardCode, GuardDiagnostic, GuardReport};
use evoengineer::llm::{
    self, GenerationRequest, Provider, ProviderSpec, RecordingProvider, ReplayProvider,
    SimProvider, MODELS,
};
use evoengineer::methods::RepairPolicy;
use evoengineer::report;
use evoengineer::runtime::Runtime;
use evoengineer::store::TranscriptStore;
use evoengineer::tasks::TaskRegistry;
use evoengineer::util::Rng;

fn evaluator() -> Evaluator {
    let reg = Arc::new(
        TaskRegistry::load(PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")).unwrap(),
    );
    Evaluator::new(reg, Runtime::new().unwrap())
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "evo_provider_{tag}_{}_{}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .subsec_nanos()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

const PROMPT: &str = "## TASK\nop: matmul_64\ncategory: 1 (MatMul)\nflops: 1e6\nbytes: 1e5\n\
baseline_time_us: 10.0\nobjective: minimize\n\n## INSTRUCTION\nImprove.\n";

fn sample_report() -> GuardReport {
    GuardReport {
        diagnostics: vec![GuardDiagnostic {
            code: GuardCode::UndefinedRef,
            field: "semantics".into(),
            message: "undefined semantics variant `turbo`".into(),
            hint: Some(("semantics".into(), "opt".into())),
        }],
    }
}

#[test]
fn sim_provider_is_byte_identical_to_the_legacy_simllm() {
    // Golden identity: provider output == legacy free-function output
    // for the same derived seed, across both roles, many trials, and
    // all three model profiles.
    let sim = SimProvider::new();
    for (mi, profile) in MODELS.iter().enumerate() {
        let session_rng =
            Rng::new(7).derive(&format!("EvoEngineer-Free/{}/matmul_64/7", profile.name));
        for trial in 0..12 {
            let label = format!("llm/{trial}");
            let seed = session_rng.derive_seed(&label);
            let legacy = llm::generate(PROMPT, profile, &mut session_rng.derive(&label));
            let got = sim
                .call(&GenerationRequest::generate(profile.name, PROMPT, seed))
                .unwrap();
            assert_eq!(got.text, legacy.text, "model {mi} trial {trial}");
            assert_eq!(got.insight, legacy.insight, "model {mi} trial {trial}");
            assert_eq!(got.usage.prompt_tokens, legacy.prompt_tokens);
            assert_eq!(got.usage.completion_tokens, legacy.completion_tokens);
        }
        // Repair role: same identity against llm::repair.
        let report = sample_report();
        let src = "kernel matmul_64 { semantics: turbo; schedule { tile_m: 8; } }";
        for attempt in 0..4 {
            let label = format!("repair/0/{attempt}");
            let seed = session_rng.derive_seed(&label);
            let legacy =
                llm::repair(src, &report, profile, &mut session_rng.derive(&label));
            let got = sim
                .call(&GenerationRequest::repair(profile.name, src, &report, seed))
                .unwrap();
            assert_eq!(got.text, legacy.text, "model {mi} attempt {attempt}");
            assert_eq!(got.insight, legacy.insight);
            assert_eq!(got.usage.prompt_tokens, legacy.prompt_tokens);
            assert_eq!(got.usage.completion_tokens, legacy.completion_tokens);
        }
    }
}

#[test]
fn conformance_roundtrip_across_sim_recording_and_replay() {
    let dir = tmpdir("conf");
    let path = dir.join("transcripts.jsonl");
    let gen_req = GenerationRequest::generate("GPT-4.1", PROMPT, 0xDEAD_BEEF_CAFE_F00D);
    let rep_req = GenerationRequest::repair(
        "Claude-Sonnet-4",
        "kernel matmul_64 { semantics: turbo; schedule { tile_m: 8; } }",
        &sample_report(),
        99,
    );

    // Bare sim backend: real, positive token accounting on both roles.
    let sim = Arc::new(SimProvider::new());
    let sim_gen = sim.call(&gen_req).unwrap();
    let sim_rep = sim.call(&rep_req).unwrap();
    for r in [&sim_gen, &sim_rep] {
        assert!(r.usage.prompt_tokens > 0);
        assert!(r.usage.completion_tokens > 0);
        assert!(!r.text.is_empty());
    }
    assert_eq!(sim.calls(), 2);

    // Recording is transparent: identical responses, inner label kept.
    let journal = TranscriptStore::open(&path).unwrap();
    let inner: Arc<dyn Provider> = sim.clone();
    let recording = RecordingProvider::new(inner, journal.clone()).unwrap();
    assert_eq!(recording.label(), "sim");
    assert_eq!(recording.call(&gen_req).unwrap(), sim_gen);
    assert_eq!(recording.call(&rep_req).unwrap(), sim_rep);
    assert_eq!(journal.len(), 2);
    // Re-issuing an identical request re-serves (inner) and does not
    // duplicate the journal entry.
    assert_eq!(recording.call(&gen_req).unwrap(), sim_gen);
    assert_eq!(journal.len(), 2);

    // Replay: byte-identical responses, impersonated source label,
    // zero live backend behind it.
    let live_before = sim.calls();
    let replay = ReplayProvider::open(&path).unwrap();
    assert_eq!(replay.label(), "sim");
    assert_eq!(replay.len(), 2);
    assert_eq!(replay.call(&gen_req).unwrap(), sim_gen);
    assert_eq!(replay.call(&rep_req).unwrap(), sim_rep);
    assert_eq!(sim.calls(), live_before, "replay must not touch the sim backend");

    // A request the journal does not cover is a hard error (the
    // zero-live-generation guarantee), with an actionable message.
    let fresh = GenerationRequest::generate("GPT-4.1", PROMPT, 12345);
    let err = replay.call(&fresh).unwrap_err().to_string();
    assert!(err.contains("transcript miss"), "{err}");

    // Opening a journal that does not exist is a front-loaded error.
    assert!(ReplayProvider::open(dir.join("missing.jsonl")).is_err());

    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn request_hashes_are_stable_across_runs_of_the_same_grid() {
    // The replay contract depends on request hashes being a pure
    // function of the request content: two sessions walking the same
    // (method, model, op, seed) cell must issue identical hashes.
    fn hashes() -> Vec<String> {
        let rng = Rng::new(3).derive("FunSearch/GPT-4.1/relu_64/3");
        (0..6)
            .map(|t| {
                let seed = rng.derive_seed(&format!("llm/{t}"));
                GenerationRequest::generate("GPT-4.1", PROMPT, seed).hash()
            })
            .collect()
    }
    let a = hashes();
    let b = hashes();
    assert_eq!(a, b);
    // ... and distinct trials never collide.
    let unique: std::collections::HashSet<&String> = a.iter().collect();
    assert_eq!(unique.len(), a.len());
}

#[test]
fn record_then_replay_campaign_is_bit_identical_with_zero_live_generation() {
    let dir = tmpdir("campaign");
    let transcripts = dir.join("transcripts.jsonl");
    // Category-6 ops (all four contain "cum") + repair policy: both
    // request roles flow through the journal, and the defect rates are
    // high enough that repairs reliably fire within the budget.
    let base = CampaignConfig {
        methods: vec!["evoengineer-free".into()],
        models: vec!["gpt".into()],
        seeds: vec![0, 1],
        op_filter: "cum".into(),
        budget: 8,
        repair: RepairPolicy::Repair { max_attempts: 2 },
        quiet: true,
        ..CampaignConfig::default()
    };

    let rec_cfg = CampaignConfig {
        provider: ProviderSpec::Sim,
        transcripts: Some(transcripts.clone()),
        ..base.clone()
    };
    let recorded = campaign::run(&rec_cfg, evaluator()).unwrap();
    assert!(!recorded.is_empty());
    assert!(recorded.iter().all(|r| r.provider == "sim"));
    assert!(
        recorded.iter().any(|r| r.repair_attempts > 0),
        "repair calls must flow through the journal for this test to bite"
    );
    let journal_bytes = std::fs::read(&transcripts).unwrap();
    assert!(!journal_bytes.is_empty());

    // Replay the identical grid: byte-identical records, identical
    // reports, journal untouched (nothing recorded, nothing
    // regenerated).
    let replay_cfg = CampaignConfig {
        provider: ProviderSpec::Replay(transcripts.clone()),
        transcripts: None,
        ..base.clone()
    };
    let replayed = campaign::run(&replay_cfg, evaluator()).unwrap();
    assert_eq!(recorded.len(), replayed.len());
    for (a, b) in recorded.iter().zip(&replayed) {
        assert_eq!(
            a.to_json().to_string(),
            b.to_json().to_string(),
            "replayed record diverged for {}/{}",
            a.op,
            a.seed
        );
    }
    assert_eq!(report::table4(&recorded), report::table4(&replayed));
    assert_eq!(report::tokens(&recorded), report::tokens(&replayed));
    assert_eq!(
        journal_bytes,
        std::fs::read(&transcripts).unwrap(),
        "replay must not append to the transcript journal"
    );

    // A wider grid than the journal covers fails loudly instead of
    // silently regenerating the missing cells.
    let widened = CampaignConfig {
        provider: ProviderSpec::Replay(transcripts.clone()),
        seeds: vec![0, 1, 2],
        ..base.clone()
    };
    // {:#} prints the whole context chain: "cell … / seed 2: transcript
    // miss …" — the campaign names the failing cell, the provider the
    // missing call.
    let err = format!("{:#}", campaign::run(&widened, evaluator()).unwrap_err());
    assert!(err.contains("transcript miss"), "{err}");
    assert!(err.contains("seed 2"), "{err}");

    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn engine_sinks_and_prefetch_do_not_perturb_campaign_identity() {
    // The trial engine (DESIGN.md §13) now drives every campaign cell.
    // Attaching an event journal and enabling speculative prefetch are
    // pure observers/accelerators: records must stay byte-identical to
    // the plain sweep (the golden sim-identity above therefore extends
    // through the engine unchanged).
    let dir = tmpdir("engine");
    let base = CampaignConfig {
        methods: vec!["evoengineer-free".into(), "eoh".into()],
        models: vec!["claude".into()],
        seeds: vec![0],
        op_filter: "softmax_64".into(),
        budget: 6,
        quiet: true,
        ..CampaignConfig::default()
    };
    let plain = campaign::run(&base, evaluator()).unwrap();
    let instrumented = CampaignConfig {
        events: Some(dir.join("events.jsonl")),
        prefetch: 3,
        ..base.clone()
    };
    let observed = campaign::run(&instrumented, evaluator()).unwrap();
    assert_eq!(plain.len(), observed.len());
    for (a, b) in plain.iter().zip(&observed) {
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    }
    assert!(dir.join("events.jsonl").exists());
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn records_carry_the_provider_label_through_json() {
    let cfg = CampaignConfig {
        methods: vec!["funsearch".into()],
        models: vec!["claude".into()],
        seeds: vec![0],
        op_filter: "relu_64".into(),
        budget: 4,
        quiet: true,
        ..CampaignConfig::default()
    };
    let records = campaign::run(&cfg, evaluator()).unwrap();
    assert!(records.iter().all(|r| r.provider == "sim"));
    let line = records[0].to_json().to_string();
    assert!(line.contains("\"provider\":\"sim\""), "{line}");
    // Pre-provider record files (no `provider` field) default to sim.
    let v = evoengineer::util::json::parse(&line.replace("\"provider\":\"sim\",", "")).unwrap();
    let back = evoengineer::methods::KernelRunRecord::from_json(&v).unwrap();
    assert_eq!(back.provider, "sim");
}
