//! Group-commit crash tests (DESIGN.md §14): a SIGKILL landing BETWEEN
//! a trial's buffered journal appends and the trial-boundary flush
//! point loses exactly the staged suffix — whole lines that never
//! reached the file descriptor, plus possibly one torn line that was
//! mid-write. A campaign resumed from that state must re-derive
//! byte-identical records and reports (the PR 5 trial-granular resume
//! contract survives the PR 6 buffering).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use evoengineer::campaign::{self, CampaignConfig};
use evoengineer::evals::Evaluator;
use evoengineer::report;
use evoengineer::runtime::Runtime;
use evoengineer::store::{
    EvalStore, EventJournal, IndexMode, TranscriptEntry, TranscriptStore,
};
use evoengineer::tasks::TaskRegistry;

fn registry() -> Arc<TaskRegistry> {
    Arc::new(
        TaskRegistry::load(PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")).unwrap(),
    )
}

fn evaluator() -> Evaluator {
    Evaluator::new(registry(), Runtime::new().unwrap())
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("evo_groupcommit_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Drop the last `n` complete lines of a journal — the staged
/// group-commit batch a kill discards before it reaches the fd.
fn chop_lines(path: &Path, n: usize) {
    let bytes = std::fs::read(path).unwrap();
    let ends: Vec<usize> = bytes
        .iter()
        .enumerate()
        .filter(|(_, &b)| b == b'\n')
        .map(|(i, _)| i + 1)
        .collect();
    assert!(ends.len() > n, "journal too short to chop {n} lines");
    std::fs::write(path, &bytes[..ends[ends.len() - 1 - n]]).unwrap();
}

/// The full kill-at-flush-boundary simulation, end to end: interrupt a
/// campaign mid-cell, then rewind its journals to the state a dirty
/// group-commit buffer leaves behind — the staged burst gone, the
/// mid-write line torn — and resume.
#[test]
fn kill_with_staged_group_commit_buffer_resumes_byte_identical() {
    let dir = tmpdir("campaign");
    let checkpoint = dir.join("records.checkpoint.jsonl");
    let cache = dir.join("eval_cache.jsonl");
    let events_path = dir.join("events.jsonl");
    let base = CampaignConfig {
        methods: vec!["evoengineer-free".into(), "funsearch".into()],
        models: vec!["gpt".into()],
        seeds: vec![0],
        op_filter: "relu_64".into(),
        budget: 4,
        quiet: true,
        concurrency: 1,
        ..CampaignConfig::default()
    };

    // Reference: one uninterrupted run, no persistence at all.
    let full = campaign::run(&base, evaluator()).unwrap();
    assert_eq!(full.len(), 2);

    // Leg 1: checkpoint + cache + events, killed after 6 trial groups
    // (cell 1 takes 4, so the kill lands mid-cell-2).
    let leg1 = CampaignConfig {
        checkpoint: Some(checkpoint.clone()),
        events: Some(events_path.clone()),
        stop_after_trials: 6,
        ..base.clone()
    };
    let partial = campaign::run(&leg1, evaluator().with_store(EvalStore::open(&cache).unwrap()))
        .unwrap();
    assert_eq!(partial.len(), 1, "the second cell was killed mid-run");

    // campaign::run exits cleanly, so its journals are fully flushed.
    // Rewind them to the crash state: the kill's dirty buffer loses
    // the last trial's staged event burst as whole lines, and the line
    // that was crossing the fd when the process died is torn mid-byte.
    let flushed = EventJournal::load(&events_path).unwrap();
    chop_lines(&events_path, 3);
    chop_lines(&cache, 1);
    {
        use std::io::Write as _;
        let mut f = std::fs::OpenOptions::new().append(true).open(&events_path).unwrap();
        write!(f, "{{\"type\":\"event\",\"kind\":\"trial").unwrap();
    }
    let crashed = EventJournal::load(&events_path).unwrap();
    assert!(
        crashed.len() <= flushed.len() - 3,
        "crash must have lost the staged burst ({} -> {})",
        flushed.len(),
        crashed.len()
    );

    // Leg 2: resume from the crashed journals. Torn tails repair, the
    // lost trials replay live (same RNG stream, warm cache for what
    // survived), and the result is byte-identical to the reference.
    let leg2 = CampaignConfig { resume: true, stop_after_trials: 0, ..leg1.clone() };
    let resumed = campaign::run(&leg2, evaluator().with_store(EvalStore::open(&cache).unwrap()))
        .unwrap();
    assert_eq!(resumed.len(), full.len());
    for (a, b) in full.iter().zip(&resumed) {
        assert_eq!(
            a.to_json().to_string(),
            b.to_json().to_string(),
            "crash-at-flush-boundary resume diverged for {}/{}",
            a.method,
            a.op
        );
    }
    assert_eq!(report::table4(&full), report::table4(&resumed));
    assert_eq!(report::fig1(&full), report::fig1(&resumed));
    assert_eq!(report::tokens(&full), report::tokens(&resumed));
    std::fs::remove_dir_all(&dir).ok();
}

/// Store-level contract behind the campaign test, for the transcript
/// journal (the eval cache and event journal have unit-level twins in
/// their own modules): a kill between append and flush loses exactly
/// the staged calls, never flushed ones, and never the meta line.
#[test]
fn transcript_kill_loses_only_staged_calls() {
    let dir = tmpdir("transcript");
    let path = dir.join("transcripts.jsonl");
    let entry = |seed: u64| TranscriptEntry {
        role: "generate".into(),
        model: "GPT-4.1".into(),
        seed,
        text: "kernel relu_64 { semantics: opt; }".into(),
        insight: "baseline".into(),
        prompt_tokens: 10,
        completion_tokens: 5,
    };
    {
        let t = TranscriptStore::open_with(&path, IndexMode::Off).unwrap();
        t.record_source("sim").unwrap(); // identity line flushes through
        t.append("k_durable", entry(1)).unwrap();
        t.flush().unwrap();
        t.append("k_staged", entry(2)).unwrap();
        t.drop_unflushed(); // simulated SIGKILL with a dirty buffer
    }
    let t = TranscriptStore::open_with(&path, IndexMode::Off).unwrap();
    assert_eq!(t.source().as_deref(), Some("sim"));
    assert_eq!(t.lookup("k_durable"), Some(entry(1)));
    assert_eq!(t.lookup("k_staged"), None, "staged call must die with the buffer");
    assert_eq!(t.len(), 1);
    std::fs::remove_dir_all(&dir).ok();
}
