//! Trial-engine conformance suite (DESIGN.md §13).
//!
//! Four contracts:
//!
//! 1. **Golden identity vs the pre-redesign monolith.** `mod legacy`
//!    below is a verbatim reimplementation of the blocking
//!    `Method::run` era — the old `Session::trial` body and all six
//!    method loops, exactly as they shipped — built purely on public
//!    APIs. Every method's engine-driven record must be byte-identical
//!    to the legacy record for the same seeds (same RNG derivation
//!    order, same emissions, same token accounting, same trajectory).
//! 2. **Prefetch identity.** Speculative generation prefetch changes
//!    wall-clock behaviour only: records with `prefetch: N` are
//!    byte-identical to `prefetch: 0`, including when repairs shift
//!    trial indices and force mis-speculation.
//! 3. **Trial-granular resume.** A campaign killed *mid-cell* by the
//!    `stop_after_trials` gate resumes (eval cache + transcript reuse)
//!    to records and reports byte-identical to an uninterrupted run —
//!    across both the sim and replay providers.
//! 4. **Event-journal format.** Events round-trip through
//!    `events.jsonl`, a live `MetricsSink` agrees with a journal
//!    re-fold, and a bundled fixture journal guards the line format
//!    against drift.

use std::path::PathBuf;
use std::sync::Arc;

use evoengineer::campaign::{self, CampaignConfig};
use evoengineer::evals::Evaluator;
use evoengineer::llm::{ProviderSpec, SimProvider, MODELS};
use evoengineer::methods::engine::{self, EngineOpts};
use evoengineer::methods::{self, Archive, JournalSink, MetricsSink, RepairPolicy, RunCtx};
use evoengineer::metrics::EventStats;
use evoengineer::report;
use evoengineer::runtime::Runtime;
use evoengineer::store::events::{self, EventJournal};
use evoengineer::store::EvalStore;
use evoengineer::tasks::TaskRegistry;

fn registry() -> Arc<TaskRegistry> {
    Arc::new(
        TaskRegistry::load(PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")).unwrap(),
    )
}

fn evaluator() -> Evaluator {
    Evaluator::new(registry(), Runtime::new().unwrap())
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "evo_engine_{tag}_{}_{}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .subsec_nanos()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// A verbatim reimplementation of the pre-redesign blocking pipeline:
/// the monolithic `Session` (guidance assembly → provider call →
/// guard/repair → evaluation → bookkeeping in one method) and the six
/// method loops that drove it. This is the golden reference the
/// event-driven engine must match byte-for-byte.
mod legacy {
    use evoengineer::costmodel::{baseline_schedule, price};
    use evoengineer::dsl;
    use evoengineer::evals::EvalOutcome;
    use evoengineer::llm::GenerationRequest;
    use evoengineer::methods::{ArchiveEntry, KernelRunRecord, RepairPolicy, RunCtx};
    use evoengineer::population::{Candidate, Elite, Islands, Population, SingleBest};
    use evoengineer::traverse::prompt::{profiling_line, render};
    use evoengineer::traverse::{Guidance, GuidanceConfig, InsightRecord, PromptStyle};
    use evoengineer::util::Rng;

    pub struct Session<'a> {
        ctx: &'a RunCtx<'a>,
        rng: Rng,
        insights: Vec<InsightRecord>,
        prompt_tokens: u64,
        completion_tokens: u64,
        trials_done: usize,
        compiled: usize,
        correct: usize,
        guard_rejected: usize,
        repaired: usize,
        repair_attempts: usize,
        best: Option<Candidate>,
        best_pt: f64,
        trajectory: Vec<f64>,
    }

    impl<'a> Session<'a> {
        pub fn new(ctx: &'a RunCtx<'a>, method_name: &str) -> Self {
            let rng = Rng::new(ctx.seed).derive(&format!(
                "{method_name}/{}/{}/{}",
                ctx.model.name, ctx.task.name, ctx.seed
            ));
            Session {
                ctx,
                rng,
                insights: Vec::new(),
                prompt_tokens: 0,
                completion_tokens: 0,
                trials_done: 0,
                compiled: 0,
                correct: 0,
                guard_rejected: 0,
                repaired: 0,
                repair_attempts: 0,
                best: None,
                best_pt: 0.0,
                trajectory: Vec::new(),
            }
        }

        fn budget_left(&self) -> usize {
            self.ctx.budget.saturating_sub(self.trials_done)
        }

        fn bootstrap(&mut self, pop: &mut dyn Population) {
            let spec = dsl::KernelSpec {
                op: self.ctx.task.name.clone(),
                semantics: "opt".into(),
                schedule: baseline_schedule(self.ctx.task),
            };
            let src = dsl::print(&spec);
            let mut rng = self.rng.derive("bootstrap");
            let outcome = self.ctx.evaluator.evaluate_keyed(
                &src,
                self.ctx.task,
                self.ctx.model.name,
                &mut rng,
            );
            let cand = self.candidate_from(src, outcome, 0, None);
            pop.insert(cand);
        }

        fn candidate_from(
            &mut self,
            src: String,
            outcome: EvalOutcome,
            trial: usize,
            insight: Option<String>,
        ) -> Candidate {
            let spec = dsl::parse(&src).ok();
            let (speedup, pt, true_speedup, true_pt) = match &outcome {
                EvalOutcome::Ok(s) => {
                    (s.speedup, s.pytorch_speedup, s.true_speedup, s.true_pytorch_speedup)
                }
                _ => (1.0, 0.0, 1.0, 0.0),
            };
            Candidate {
                src,
                spec,
                compiled: outcome.compiled(),
                correct: outcome.correct(),
                speedup,
                pytorch_speedup: pt,
                true_speedup,
                true_pytorch_speedup: true_pt,
                insight,
                trial,
            }
        }

        fn top_insights(&self, k: usize) -> Vec<&InsightRecord> {
            let mut v: Vec<&InsightRecord> = self.insights.iter().collect();
            v.sort_by(|a, b| b.delta.total_cmp(&a.delta));
            v.truncate(k);
            v
        }

        fn trial(
            &mut self,
            cfg: &GuidanceConfig,
            pop: &mut dyn Population,
            instruction: &str,
            parent_override: Option<Candidate>,
            history_override: Option<Vec<Candidate>>,
        ) -> evoengineer::Result<Option<Candidate>> {
            if self.budget_left() == 0 {
                return Ok(None);
            }
            let trial_idx = self.trials_done;
            let mut trial_rng = self.rng.derive(&format!("trial/{trial_idx}"));

            let parent = parent_override.or_else(|| pop.parent(&mut trial_rng));
            let history: Vec<Candidate> = match history_override {
                Some(h) => h,
                None => pop.history(cfg.n_history),
            };
            let insights = self.top_insights(cfg.n_insights);
            let profiling = if cfg.profiling {
                parent.as_ref().and_then(|p| {
                    p.spec.as_ref().map(|spec| {
                        let t = price(&spec.schedule, self.ctx.task, &self.ctx.evaluator.gpu);
                        profiling_line(&t)
                    })
                })
            } else {
                None
            };
            let baseline_us = self.ctx.evaluator.baseline_time(self.ctx.task) * 1e6;
            let guidance = Guidance {
                task: self.ctx.task,
                baseline_us,
                parent: parent.as_ref(),
                history: history.iter().collect(),
                insights,
                profiling,
                instruction: instruction.to_string(),
            };

            let prompt = render(cfg, &guidance);
            let llm_seed = self.rng.derive_seed(&format!("llm/{trial_idx}"));
            let req = GenerationRequest::generate(self.ctx.model.name, &prompt, llm_seed);
            let resp = self.ctx.provider.call(&req)?;
            self.prompt_tokens += resp.usage.prompt_tokens;
            self.completion_tokens += resp.usage.completion_tokens;
            self.trials_done += 1;

            let mut text = resp.text;
            let mut was_repaired = false;
            let guard_report = match self.ctx.repair {
                RepairPolicy::Off => None,
                RepairPolicy::Diagnose => {
                    Some(self.ctx.evaluator.guard_check(&text, self.ctx.task))
                }
                RepairPolicy::Repair { max_attempts } => {
                    let mut report = self.ctx.evaluator.guard_check(&text, self.ctx.task);
                    let initially_failed = !report.pass();
                    let mut attempt = 0;
                    while !report.pass() && attempt < max_attempts && self.budget_left() > 0 {
                        let repair_seed =
                            self.rng.derive_seed(&format!("repair/{trial_idx}/{attempt}"));
                        let req = GenerationRequest::repair(
                            self.ctx.model.name,
                            &text,
                            &report,
                            repair_seed,
                        );
                        let fix = self.ctx.provider.call(&req)?;
                        self.prompt_tokens += fix.usage.prompt_tokens;
                        self.completion_tokens += fix.usage.completion_tokens;
                        self.trials_done += 1;
                        self.repair_attempts += 1;
                        text = fix.text;
                        report = self.ctx.evaluator.guard_check(&text, self.ctx.task);
                        attempt += 1;
                    }
                    if initially_failed && report.pass() {
                        was_repaired = true;
                    }
                    Some(report)
                }
            };

            let mut eval_rng = self.rng.derive(&format!("eval/{trial_idx}"));
            let outcome = match &guard_report {
                Some(report) if !report.pass() => {
                    self.guard_rejected += 1;
                    self.ctx.evaluator.reject_stage0(
                        &text,
                        self.ctx.task,
                        self.ctx.model.name,
                        report,
                    )
                }
                _ => self.ctx.evaluator.evaluate_keyed(
                    &text,
                    self.ctx.task,
                    self.ctx.model.name,
                    &mut eval_rng,
                ),
            };
            if was_repaired {
                self.repaired += 1;
            }
            if outcome.compiled() {
                self.compiled += 1;
            }
            if outcome.correct() {
                self.correct += 1;
            }

            let cand = self.candidate_from(text, outcome, trial_idx, Some(resp.insight.clone()));

            let delta = if cand.valid() {
                let parent_speed = parent.as_ref().filter(|p| p.valid()).map(|p| p.speedup);
                match parent_speed {
                    Some(ps) => cand.speedup - ps,
                    None => cand.speedup - 1.0,
                }
            } else {
                -0.30
            };
            self.insights.push(InsightRecord { text: resp.insight, delta });
            if self.insights.len() > 128 {
                self.insights.sort_by(|a, b| b.delta.total_cmp(&a.delta));
                self.insights.truncate(64);
            }

            if cand.valid()
                && self
                    .best
                    .as_ref()
                    .map(|b| cand.speedup > b.speedup)
                    .unwrap_or(true)
            {
                self.best = Some(cand.clone());
            }
            if cand.valid() {
                self.best_pt = self.best_pt.max(cand.true_pytorch_speedup);
            }
            self.trajectory
                .push(self.best.as_ref().map(|b| b.true_speedup).unwrap_or(1.0).max(1.0));

            pop.insert(cand.clone());
            Ok(Some(cand))
        }

        fn finish(self, method_name: &str) -> KernelRunRecord {
            if let Some(best) = &self.best {
                self.ctx.archive.record(ArchiveEntry {
                    op: self.ctx.task.name.clone(),
                    family: self.ctx.task.family.clone(),
                    src: best.src.clone(),
                    speedup: best.true_speedup,
                    rank: best.true_speedup,
                });
            }
            KernelRunRecord {
                method: method_name.to_string(),
                model: self.ctx.model.name.to_string(),
                op: self.ctx.task.name.clone(),
                category: self.ctx.task.category,
                seed: self.ctx.seed,
                trials: self.trials_done,
                budget: self.ctx.budget,
                compiled_trials: self.compiled,
                correct_trials: self.correct,
                guard_rejected_trials: self.guard_rejected,
                repaired_trials: self.repaired,
                repair_attempts: self.repair_attempts,
                repair_policy: self.ctx.repair.label(),
                goal: self.ctx.feedback.label(),
                provider: self.ctx.provider.label().to_string(),
                best_speedup: self.best.as_ref().map(|b| b.true_speedup).unwrap_or(1.0).max(1.0),
                best_pytorch_speedup: self.best_pt,
                any_valid: self.best.is_some(),
                prompt_tokens: self.prompt_tokens,
                completion_tokens: self.completion_tokens,
                trajectory: self.trajectory,
                arms: vec![],
                best_src: self.best.map(|b| b.src),
            }
        }
    }

    // The instruction constants, verbatim from the pre-redesign
    // method modules.
    const EVO_IMPROVE: &str = "Improve the current kernel: propose a modified schedule that \
reduces execution time while preserving exact output semantics.";
    const EVO_INIT: &str = "Design a new kernel from scratch for this operation, optimized \
for the target device.";
    const FS_IMPROVE: &str = "Here are prior kernel versions ordered by quality. Write an \
improved next version of the kernel.";
    const E1: &str = "Design a new kernel from scratch for this operation. You may draw \
inspiration from the historical solutions, but produce a structurally different schedule.";
    const E2: &str = "Combine the historical solutions: crossover their schedule decisions \
into a single kernel that inherits the best choices of each.";
    const M1: &str = "Mutate the current kernel: change part of its schedule to explore a \
neighbouring design.";
    const M2: &str = "Tune the numeric parameters of the current kernel only (tile sizes, \
unroll factor, block size, register budget); keep its structure fixed.";
    const CONVERT: &str = "Convert the high-level operation description into an initial CUDA \
kernel implementation. Correctness first; a plain schedule is acceptable.";
    const TRANSLATE: &str = "Translate the kernel into an alternative implementation style \
while preserving semantics.";
    const OPTIMIZE: &str = "Optimize the kernel aggressively. Use the profiling data and the \
correct kernels above; consider the ensemble of optimization directions and commit to the \
fastest.";
    const COMPOSE: &str = "The kernels above come from related operations in the archive. \
Compose their optimization strategies into this operation's kernel.";
    const CONVERT_RETRIES: usize = 10;
    const COMPOSE_TRIALS: usize = 5;

    fn run_free_like(name: &str, cfg: GuidanceConfig, ctx: &RunCtx) -> KernelRunRecord {
        let mut session = Session::new(ctx, name);
        let mut pop = SingleBest::new();
        session.bootstrap(&mut pop);
        while session
            .trial(&cfg, &mut pop, EVO_IMPROVE, None, None)
            .unwrap()
            .is_some()
        {}
        session.finish(name)
    }

    fn run_full(ctx: &RunCtx) -> KernelRunRecord {
        let name = "EvoEngineer-Full";
        let cfg = GuidanceConfig::full();
        let mut session = Session::new(ctx, name);
        let mut pop = Elite::new(4);
        session.bootstrap(&mut pop);
        for _ in 0..5 {
            if session.trial(&cfg, &mut pop, EVO_INIT, None, None).unwrap().is_none() {
                break;
            }
        }
        'gens: for _gen in 0..10 {
            for _off in 0..4 {
                if session
                    .trial(&cfg, &mut pop, EVO_IMPROVE, None, None)
                    .unwrap()
                    .is_none()
                {
                    break 'gens;
                }
            }
        }
        session.finish(name)
    }

    fn run_funsearch(ctx: &RunCtx) -> KernelRunRecord {
        let name = "FunSearch";
        let cfg = GuidanceConfig::funsearch();
        let mut session = Session::new(ctx, name);
        let mut pop = Islands::funsearch();
        session.bootstrap(&mut pop);
        while session
            .trial(&cfg, &mut pop, FS_IMPROVE, None, None)
            .unwrap()
            .is_some()
        {}
        session.finish(name)
    }

    fn run_eoh(ctx: &RunCtx) -> KernelRunRecord {
        let name = "EvoEngineer-Solution (EoH)";
        let cfg = GuidanceConfig::eoh();
        let mut session = Session::new(ctx, name);
        let mut pop = Elite::new(4);
        session.bootstrap(&mut pop);
        for _ in 0..5 {
            if session.trial(&cfg, &mut pop, E1, None, None).unwrap().is_none() {
                return session.finish(name);
            }
        }
        'gens: for _gen in 0..10 {
            for op in [E1, E2, M1, M2] {
                let parent = if std::ptr::eq(op, M1) || std::ptr::eq(op, M2) {
                    pop.best()
                } else {
                    None
                };
                if session.trial(&cfg, &mut pop, op, parent, None).unwrap().is_none() {
                    break 'gens;
                }
            }
        }
        session.finish(name)
    }

    fn run_aicuda(ctx: &RunCtx) -> KernelRunRecord {
        let name = "AI CUDA Engineer";
        let mut session = Session::new(ctx, name);
        let mut pop = Elite::new(5);
        let convert_cfg = GuidanceConfig {
            n_history: 0,
            n_insights: 0,
            profiling: false,
            style: PromptStyle::Verbose,
        };
        let mut converted = false;
        for _ in 0..CONVERT_RETRIES {
            match session.trial(&convert_cfg, &mut pop, CONVERT, None, None).unwrap() {
                Some(cand) if cand.compiled => {
                    converted = true;
                    break;
                }
                Some(_) => continue,
                None => break,
            }
        }
        if !converted {
            return session.finish(name);
        }
        let _ = session.trial(&convert_cfg, &mut pop, TRANSLATE, None, None).unwrap();
        let optimize_cfg = GuidanceConfig::aicuda();
        while session.budget_left() > COMPOSE_TRIALS {
            if session
                .trial(&optimize_cfg, &mut pop, OPTIMIZE, None, None)
                .unwrap()
                .is_none()
            {
                break;
            }
        }
        let rag = ctx.archive.similar(&ctx.task.name, &ctx.task.family, 5);
        let rag_cands: Vec<Candidate> = rag
            .into_iter()
            .map(|e| Candidate {
                src: e.src,
                spec: None,
                compiled: true,
                correct: true,
                speedup: e.speedup,
                pytorch_speedup: 0.0,
                true_speedup: e.speedup,
                true_pytorch_speedup: 0.0,
                insight: None,
                trial: 0,
            })
            .collect();
        for _ in 0..COMPOSE_TRIALS {
            let history = if rag_cands.is_empty() {
                None
            } else {
                Some(rag_cands.clone())
            };
            if session
                .trial(&optimize_cfg, &mut pop, COMPOSE, None, history)
                .unwrap()
                .is_none()
            {
                break;
            }
        }
        session.finish(name)
    }

    /// Run a method's pre-redesign loop by name.
    pub fn run(method: &str, ctx: &RunCtx) -> KernelRunRecord {
        match method {
            "EvoEngineer-Free" => run_free_like("EvoEngineer-Free", GuidanceConfig::free(), ctx),
            "EvoEngineer-Insight" => {
                run_free_like("EvoEngineer-Insight", GuidanceConfig::insight(), ctx)
            }
            "EvoEngineer-Full" => run_full(ctx),
            "FunSearch" => run_funsearch(ctx),
            "EvoEngineer-Solution (EoH)" => run_eoh(ctx),
            "AI CUDA Engineer" => run_aicuda(ctx),
            other => panic!("unknown method {other}"),
        }
    }
}

#[test]
fn engine_is_byte_identical_to_the_legacy_monolith_for_all_six_methods() {
    let evaluator = evaluator();
    let task = evaluator.registry.get("matmul_64").unwrap().clone();
    for method in methods::all_methods() {
        let name = method.name();
        // Independent archives: finish() publishes to the archive, and
        // the AI CUDA Engineer's Compose stage reads it.
        let a_new = Archive::new();
        let p_new = SimProvider::new();
        let ctx_new = RunCtx {
            evaluator: &evaluator,
            task: &task,
            model: &MODELS[0],
            seed: 3,
            archive: &a_new,
            provider: &p_new,
            budget: 12,
            repair: RepairPolicy::Off,
            feedback: Default::default(),
            bank: None,
            warm: None,
        };
        let rec_new = method.run(&ctx_new).unwrap();
        let a_old = Archive::new();
        let p_old = SimProvider::new();
        let ctx_old = RunCtx {
            evaluator: &evaluator,
            task: &task,
            model: &MODELS[0],
            seed: 3,
            archive: &a_old,
            provider: &p_old,
            budget: 12,
            repair: RepairPolicy::Off,
            feedback: Default::default(),
            bank: None,
            warm: None,
        };
        let rec_old = legacy::run(&name, &ctx_old);
        assert_eq!(
            rec_new.to_json().to_string(),
            rec_old.to_json().to_string(),
            "engine diverged from the pre-redesign implementation for {name}"
        );
        assert_eq!(a_new.len(), a_old.len(), "{name}: archive publication diverged");
    }
}

#[test]
fn engine_matches_legacy_under_a_repair_policy() {
    // Category-6 ops + GPT have the highest defect rates, so the guard
    // and the budget-consuming repair loop both fire — the sequencing
    // the engine must reproduce exactly (repairs shift trial indices).
    let evaluator = evaluator();
    let task = evaluator.registry.get("cumsum_rows_64").unwrap().clone();
    let a_new = Archive::new();
    let p_new = SimProvider::new();
    let ctx_new = RunCtx {
        evaluator: &evaluator,
        task: &task,
        model: &MODELS[0],
        seed: 0,
        archive: &a_new,
        provider: &p_new,
        budget: 14,
        repair: RepairPolicy::Repair { max_attempts: 2 },
        feedback: Default::default(),
        bank: None,
        warm: None,
    };
    let rec_new = methods::by_name("evoengineer-free").unwrap().run(&ctx_new).unwrap();
    let a_old = Archive::new();
    let p_old = SimProvider::new();
    let ctx_old = RunCtx {
        evaluator: &evaluator,
        task: &task,
        model: &MODELS[0],
        seed: 0,
        archive: &a_old,
        provider: &p_old,
        budget: 14,
        repair: RepairPolicy::Repair { max_attempts: 2 },
        feedback: Default::default(),
        bank: None,
        warm: None,
    };
    let rec_old = legacy::run("EvoEngineer-Free", &ctx_old);
    assert!(rec_new.repair_attempts > 0, "repairs must fire for this test to bite");
    assert_eq!(rec_new.to_json().to_string(), rec_old.to_json().to_string());
}

#[test]
fn prefetch_is_byte_identical_to_serial_execution() {
    let evaluator = evaluator();
    // FunSearch stresses stateful speculation (island cursor snapshot);
    // Full stresses insight-bearing prompts; the repair case stresses
    // index-shifting mis-speculation.
    let cases: [(&str, &str, RepairPolicy); 3] = [
        ("funsearch", "softmax_64", RepairPolicy::Off),
        ("evoengineer-full", "matmul_64", RepairPolicy::Off),
        ("evoengineer-free", "cumsum_rows_64", RepairPolicy::Repair { max_attempts: 2 }),
    ];
    for (method, op, repair) in cases {
        let task = evaluator.registry.get(op).unwrap().clone();
        let run_with = |prefetch: usize| {
            let archive = Archive::new();
            let provider = SimProvider::new();
            let ctx = RunCtx {
                evaluator: &evaluator,
                task: &task,
                model: &MODELS[1],
                seed: 7,
                archive: &archive,
                provider: &provider,
                budget: 10,
                repair,
                feedback: Default::default(),
                bank: None,
                warm: None,
            };
            let opts = EngineOpts { prefetch, ..EngineOpts::default() };
            engine::drive(methods::by_name(method).unwrap().as_ref(), &ctx, &opts).unwrap()
        };
        let serial = run_with(0);
        let pipelined = run_with(4);
        assert_eq!(
            serial.to_json().to_string(),
            pipelined.to_json().to_string(),
            "{method}/{op}: prefetch changed the record"
        );
    }
}

#[test]
fn mid_cell_kill_resumes_to_byte_identical_records_across_providers() {
    let dir = tmpdir("resume");
    let checkpoint = dir.join("records.checkpoint.jsonl");
    let cache = dir.join("eval_cache.jsonl");
    let transcripts = dir.join("transcripts.jsonl");
    let events_path = dir.join("events.jsonl");
    let base = CampaignConfig {
        methods: vec!["evoengineer-free".into(), "funsearch".into()],
        models: vec!["gpt".into()],
        seeds: vec![0],
        op_filter: "relu_64".into(),
        budget: 4,
        quiet: true,
        concurrency: 1,
        ..CampaignConfig::default()
    };

    // Reference: one uninterrupted run, no persistence at all.
    let full = campaign::run(&base, evaluator()).unwrap();
    assert_eq!(full.len(), 2);

    // Leg 1: checkpoint + cache + transcripts + events, killed after 6
    // trial groups — cell 1 takes 4, so the kill lands mid-cell-2 with
    // exactly 2 of its trials complete (claim-gated, deterministic).
    let leg1 = CampaignConfig {
        checkpoint: Some(checkpoint.clone()),
        transcripts: Some(transcripts.clone()),
        events: Some(events_path.clone()),
        stop_after_trials: 6,
        ..base.clone()
    };
    let partial = campaign::run(&leg1, evaluator().with_store(EvalStore::open(&cache).unwrap()))
        .unwrap();
    assert_eq!(partial.len(), 1, "the second cell was killed mid-run");

    // The event journal pinpoints the half-finished cell and its
    // completed trials.
    let evs = EventJournal::load(&events_path).unwrap();
    let half = events::completed_trials(&evs);
    assert_eq!(half.len(), 1, "exactly one half-finished cell: {half:?}");
    let (cell, trials) = half.iter().next().unwrap();
    assert_eq!(cell.0, "FunSearch", "job order: Free completed, FunSearch was cut");
    assert_eq!(
        trials.iter().map(|(t, _)| *t).collect::<Vec<_>>(),
        vec![0, 1],
        "two trial groups completed before the kill"
    );

    // Leg 2: resume. Completed trials replay warm (eval cache +
    // transcript reuse, verified against the event journal); the cell
    // continues live from trial 2. Byte-identical to the reference.
    let leg2 = CampaignConfig {
        resume: true,
        stop_after_trials: 0,
        ..leg1.clone()
    };
    let resumed = campaign::run(&leg2, evaluator().with_store(EvalStore::open(&cache).unwrap()))
        .unwrap();
    assert_eq!(resumed.len(), full.len());
    for (a, b) in full.iter().zip(&resumed) {
        assert_eq!(
            a.to_json().to_string(),
            b.to_json().to_string(),
            "trial-granular resume diverged for {}/{}",
            a.method,
            a.op
        );
    }
    assert_eq!(report::table4(&full), report::table4(&resumed));
    assert_eq!(report::tokens(&full), report::tokens(&resumed));

    // The resumed leg must not re-journal the replayed trials: across
    // the kill the journal reads as one continuous event stream per
    // cell, so `report events` never double-counts a cell.
    let evs_after = EventJournal::load(&events_path).unwrap();
    let stats_after = EventStats::from_events(&evs_after);
    assert_eq!(stats_after.runs_started, 2, "one run_started per cell");
    assert_eq!(stats_after.runs_finished, 2);
    assert_eq!(stats_after.groups, 8, "2 cells x 4 trials, no duplicates");
    let full_tokens: u64 = full.iter().map(|r| r.prompt_tokens).sum();
    assert_eq!(stats_after.prompt_tokens, full_tokens, "journaled tokens counted once");
    assert!(events::completed_trials(&evs_after).is_empty(), "both cells finished");

    // The two legs together fully covered the transcript journal, so a
    // replay-provider sweep of the same grid is byte-identical with
    // zero live generation.
    let replayed = campaign::run(
        &CampaignConfig {
            provider: ProviderSpec::Replay(transcripts.clone()),
            transcripts: None,
            ..base.clone()
        },
        evaluator(),
    )
    .unwrap();
    for (a, b) in full.iter().zip(&replayed) {
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    }

    // And the same mid-cell kill + resume works *under replay* too:
    // trial-granular resume is provider-agnostic.
    let r_dir = tmpdir("resume_replay");
    let r_ckpt = r_dir.join("ckpt.jsonl");
    let killed = CampaignConfig {
        provider: ProviderSpec::Replay(transcripts.clone()),
        transcripts: None,
        checkpoint: Some(r_ckpt.clone()),
        stop_after_trials: 6,
        ..base.clone()
    };
    let partial_replay = campaign::run(&killed, evaluator()).unwrap();
    assert_eq!(partial_replay.len(), 1);
    let resumed_replay = campaign::run(
        &CampaignConfig { resume: true, stop_after_trials: 0, ..killed.clone() },
        evaluator(),
    )
    .unwrap();
    for (a, b) in full.iter().zip(&resumed_replay) {
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    }

    std::fs::remove_dir_all(dir).ok();
    std::fs::remove_dir_all(r_dir).ok();
}

#[test]
fn event_journal_agrees_with_the_run_record_and_the_live_sink() {
    let dir = tmpdir("events");
    let path = dir.join("events.jsonl");
    let evaluator = evaluator();
    let task = evaluator.registry.get("cumsum_rows_64").unwrap().clone();
    let archive = Archive::new();
    let provider = SimProvider::new();
    let ctx = RunCtx {
        evaluator: &evaluator,
        task: &task,
        model: &MODELS[0],
        seed: 1,
        archive: &archive,
        provider: &provider,
        budget: 10,
        repair: RepairPolicy::Repair { max_attempts: 2 },
        feedback: Default::default(),
        bank: None,
        warm: None,
    };
    let metrics_sink = Arc::new(MetricsSink::new());
    let journal_sink: Arc<dyn methods::EventSink> =
        Arc::new(JournalSink::new(EventJournal::create(&path).unwrap()));
    let metrics_dyn: Arc<dyn methods::EventSink> = metrics_sink.clone();
    let opts = EngineOpts {
        sinks: vec![journal_sink, metrics_dyn],
        ..EngineOpts::default()
    };
    let rec = engine::drive(
        methods::by_name("evoengineer-free").unwrap().as_ref(),
        &ctx,
        &opts,
    )
    .unwrap();

    let evs = EventJournal::load(&path).unwrap();
    let stats = EventStats::from_events(&evs);

    // The journal's aggregate must agree with the record exactly…
    assert_eq!(stats.runs_started, 1);
    assert_eq!(stats.runs_finished, 1);
    assert_eq!(stats.budget_exhausted, 1, "a 10-unit budget run exhausts its budget");
    assert_eq!(stats.groups, rec.trials - rec.repair_attempts);
    assert_eq!(stats.repair_attempts, rec.repair_attempts);
    assert_eq!(stats.prompt_tokens, rec.prompt_tokens);
    assert_eq!(stats.completion_tokens, rec.completion_tokens);
    assert_eq!(stats.best_speedup, rec.best_speedup);
    assert_eq!(
        *stats.outcomes.get("guard_reject").unwrap_or(&0),
        rec.guard_rejected_trials
    );
    // …and with the live metrics sink, fold for fold.
    let live = metrics_sink.stats();
    assert_eq!(live.groups, stats.groups);
    assert_eq!(live.outcomes, stats.outcomes);
    assert_eq!(live.prompt_tokens, stats.prompt_tokens);

    // Every event belongs to the one cell, and kinds appear in a sane
    // order: run_started first, run_finished last.
    assert!(evs.iter().all(|e| e.op == "cumsum_rows_64" && e.seed == 1));
    assert_eq!(evs.first().unwrap().kind.label(), "run_started");
    assert_eq!(evs.last().unwrap().kind.label(), "run_finished");

    // The rendered report mentions the headline numbers.
    let rendered = report::events(&evs);
    assert!(rendered.contains("1 started"), "{rendered}");

    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn bundled_event_journal_fixture_guards_the_format() {
    // The fixture is a committed journal written by the current
    // serializer. Parsing it AND re-serializing back to the identical
    // bytes pins the line format: any drift (renamed field, reordered
    // keys, changed kind label) fails here before it can strand
    // already-journaled events in the wild.
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/data/events.fixture.jsonl");
    let raw = std::fs::read_to_string(&path).unwrap();
    let evs = EventJournal::load(&path).unwrap();
    assert_eq!(evs.len(), raw.lines().filter(|l| !l.trim().is_empty()).count());

    let reserialized: String = evs
        .iter()
        .map(|e| events::event_to_json(e).to_string() + "\n")
        .collect();
    assert_eq!(raw, reserialized, "event journal format drifted from the fixture");

    // The fixture exercises every kind exactly once…
    let kinds: std::collections::BTreeSet<&'static str> =
        evs.iter().map(|e| e.kind.label()).collect();
    assert_eq!(kinds.len(), 8, "fixture must cover the full taxonomy: {kinds:?}");

    // …and folds into the expected aggregate.
    let stats = EventStats::from_events(&evs);
    assert_eq!(stats.runs_started, 1);
    assert_eq!(stats.runs_finished, 1);
    assert_eq!(stats.groups, 1);
    assert_eq!(stats.repair_attempts, 1);
    assert_eq!(stats.repairs_mended, 1);
    assert_eq!(stats.prompt_tokens, 321);
    assert_eq!(stats.completion_tokens, 45);
    assert_eq!(stats.new_bests, 1);

    // The half-finished-cell scan sees a finished cell → empty map.
    assert!(events::completed_trials(&evs).is_empty());
}

#[test]
fn stop_after_trials_interrupts_exactly_at_the_claimed_trial() {
    // claim semantics: with a limit of 1, the very first cell dies on
    // its second trial group, so no record is ever produced.
    let cfg = CampaignConfig {
        methods: vec!["funsearch".into()],
        models: vec!["gpt".into()],
        seeds: vec![0],
        op_filter: "relu_64".into(),
        budget: 3,
        quiet: true,
        concurrency: 1,
        stop_after_trials: 1,
        ..CampaignConfig::default()
    };
    let records = campaign::run(&cfg, evaluator()).unwrap();
    assert!(records.is_empty(), "{records:?}");

    // A limit beyond the grid's total trial demand never fires.
    let cfg = CampaignConfig { stop_after_trials: 100, ..cfg };
    let records = campaign::run(&cfg, evaluator()).unwrap();
    assert_eq!(records.len(), 1);
    assert_eq!(records[0].trials, 3);
}
