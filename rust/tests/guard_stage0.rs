//! Stage-0 guard integration tests (DESIGN.md §11): the guard against
//! real manifest ops, the invalid-candidate taxonomy, edge-case shapes,
//! and the cache-level guarantees — guard-rejected candidates never
//! reach the PJRT runtime pool, and guarded runs replay bit-identically
//! from the persistent store.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

use evoengineer::costmodel::baseline_schedule;
use evoengineer::dsl::{self, KernelSpec};
use evoengineer::evals::{EvalOutcome, Evaluator};
use evoengineer::guard::{self, GuardCode};
use evoengineer::llm::{SimProvider, MODELS};
use evoengineer::methods::{EvoEngineer, EvoVariant, Method};
use evoengineer::methods::{Archive, RepairPolicy, RunCtx};
use evoengineer::runtime::Runtime;
use evoengineer::store::EvalStore;
use evoengineer::tasks::{ArgSpec, OpTask, TaskRegistry};
use evoengineer::util::Rng;

fn registry() -> Arc<TaskRegistry> {
    Arc::new(
        TaskRegistry::load(PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")).unwrap(),
    )
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "evo_guard_{tag}_{}_{}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .subsec_nanos()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn synthetic_task(args: Vec<Vec<usize>>, out: Vec<usize>) -> OpTask {
    let mut artifacts = HashMap::new();
    artifacts.insert("ref".to_string(), "x/ref.hlo.txt".to_string());
    artifacts.insert("opt".to_string(), "x/opt.hlo.txt".to_string());
    OpTask {
        name: "synthetic".into(),
        category: 1,
        family: "x".into(),
        args: args
            .into_iter()
            .map(|shape| ArgSpec { shape, gen: "uniform".into() })
            .collect(),
        out_shape: out,
        flops: 1.0,
        bytes_moved: 1.0,
        pt_launches: 1,
        pt_passes: 1.0,
        pt_efficiency: 0.5,
        algo_penalty: 1.0,
        atol: 1e-4,
        rtol: 1e-3,
        artifacts,
    }
}

/// Calibration contract: the guard must accept the dataset's shipped
/// starting kernel for every one of the 91 ops — the bootstrap is
/// ground truth, and a guarded run whose own baseline were rejected
/// would be meaningless.
#[test]
fn guard_passes_every_baseline_kernel() {
    let reg = registry();
    for op in &reg.ops {
        let spec = KernelSpec {
            op: op.name.clone(),
            semantics: "opt".into(),
            schedule: baseline_schedule(op),
        };
        let report = guard::check_source(&dsl::print(&spec), op);
        assert!(
            report.pass(),
            "{}: baseline rejected by stage-0 guard:\n{}",
            op.name,
            report.summary()
        );
    }
}

/// The invalid-candidate taxonomy: each class rejected with a
/// structured diagnostic carrying the right code.
#[test]
fn invalid_classes_rejected_with_structured_diagnostics() {
    let reg = registry();
    let task = reg.get("matmul_64").unwrap();
    let base = KernelSpec::baseline("matmul_64");

    // Syntax.
    let broken = dsl::print(&base).replacen(';', " ", 1);
    assert!(guard::check_source(&broken, task).has(GuardCode::Syntax));

    // Shadowed binding.
    let shadowed =
        "kernel matmul_64 { semantics: opt; schedule { tile_m: 8; tile_m: 64; } }";
    assert!(guard::check_source(shadowed, task).has(GuardCode::ShadowedBinding));

    // Undefined refs: hallucinated variant + wrong op.
    let mut spec = base.clone();
    spec.semantics = "turbo_v9".into();
    assert!(guard::check_source(&dsl::print(&spec), task).has(GuardCode::UndefinedRef));
    let wrong = KernelSpec::baseline("softmax_64");
    assert!(guard::check_source(&dsl::print(&wrong), task).has(GuardCode::UndefinedRef));

    // Non-terminating construct (zero-step loop).
    let mut spec = base.clone();
    spec.schedule.tile_k = 0;
    assert!(guard::check_source(&dsl::print(&spec), task).has(GuardCode::NonTerminating));

    // Shape mismatch vs the op's ArgSpecs: resource-legal tile, but
    // larger than every operand axis of a 64-extent op.
    let mut spec = base.clone();
    spec.schedule.tile_m = 128;
    let report = guard::check_source(&dsl::print(&spec), task);
    assert!(report.has(GuardCode::ShapeMismatch), "{}", report.summary());
    assert!(
        !report.has(GuardCode::ResourceLimit),
        "tile_m=128 is resource-legal; only the shape check should fire: {}",
        report.summary()
    );

    // Resource limit (exhaustive structured validate).
    let mut spec = base.clone();
    spec.schedule.threads_per_block = 100;
    spec.schedule.vector_width = 3;
    let report = guard::check_source(&dsl::print(&spec), task);
    let limits = report
        .diagnostics
        .iter()
        .filter(|d| d.code == GuardCode::ResourceLimit)
        .count();
    assert_eq!(limits, 2, "{}", report.summary());
}

/// Edge cases the shape inference must handle without panicking:
/// rank-0 outputs, zero-size shapes — and stable diagnostics.
#[test]
fn rank0_and_zero_size_edge_cases() {
    // Rank-0 (scalar) output: default 8x8 tiling violates the output
    // spec; a 1x1 row-major schedule passes.
    let scalar = synthetic_task(vec![vec![64, 64]], vec![]);
    let mut spec = KernelSpec::baseline("synthetic");
    let report = guard::check_spec(&spec, &scalar);
    assert!(report.has(GuardCode::OutputSpecViolation), "{}", report.summary());
    spec.schedule.tile_m = 1;
    spec.schedule.tile_n = 1;
    assert!(guard::check_spec(&spec, &scalar).pass());

    // Zero-size arg and zero-size output.
    let degenerate = synthetic_task(vec![vec![64, 0]], vec![0]);
    let report = guard::check_spec(&KernelSpec::baseline("synthetic"), &degenerate);
    assert!(report.has(GuardCode::ShapeMismatch), "{}", report.summary());
    assert!(report.has(GuardCode::OutputSpecViolation), "{}", report.summary());

    // Diagnostics stability across repeated checks (same AST -> same
    // diagnostic list, byte for byte, including ordering).
    let again = guard::check_spec(&KernelSpec::baseline("synthetic"), &degenerate);
    assert_eq!(report, again);
}

/// The cache-level guarantee: a guard-rejected candidate is journaled
/// (under the guard-namespaced key) and never reaches the PJRT runtime
/// pool — and the guard record never shadows the full-pipeline record
/// for the same candidate.
#[test]
fn guard_rejected_candidates_never_reach_runtime_pool() {
    let reg = registry();
    let dir = tmpdir("pool");
    let cache = dir.join("cache.jsonl");

    let task = reg.get("matmul_64").unwrap().clone();
    // Compile-legal (passes stage-1 validation) but guard-rejected:
    // only stage 0 stands between this candidate and a PJRT compile.
    let mut spec = KernelSpec::baseline("matmul_64");
    spec.schedule.tile_m = 128;
    let src = dsl::print(&spec);

    {
        let ev = Evaluator::new(reg.clone(), Runtime::new().unwrap())
            .with_store(EvalStore::open(&cache).unwrap());
        let mut rng = Rng::new(0);
        let out = ev.evaluate_guarded(&src, &task, "-", &mut rng);
        let EvalOutcome::GuardReject { diagnostics } = &out else {
            panic!("expected GuardReject, got {out:?}");
        };
        assert!(!diagnostics.is_empty());
        assert!(!out.compiled() && !out.correct());
        let stats = ev.runtime_stats().unwrap();
        assert_eq!(stats.executions, 0, "guard-rejected candidate executed on PJRT");
        assert_eq!(stats.compiles, 0, "guard-rejected candidate compiled on PJRT");
        assert_eq!(ev.store().unwrap().len(), 1);
    }

    // Fresh process: the journaled verdict replays bit-identically,
    // still without touching the runtime pool.
    let first_diags = {
        let ev = Evaluator::new(reg.clone(), Runtime::new().unwrap())
            .with_store(EvalStore::open(&cache).unwrap());
        let mut rng = Rng::new(7); // guard replay consumes no RNG
        let out = ev.evaluate_guarded(&src, &task, "-", &mut rng);
        let EvalOutcome::GuardReject { diagnostics } = out else {
            panic!("expected replayed GuardReject");
        };
        assert_eq!(ev.store().unwrap().hits(), 1);
        assert_eq!(ev.runtime_stats().unwrap().executions, 0);

        // Namespacing: the same candidate through the *unguarded*
        // pipeline compiles and runs fine — the guard verdict must not
        // shadow it (and vice versa).
        let mut rng = Rng::new(1);
        let full = ev.evaluate(&src, &task, &mut rng);
        assert!(
            matches!(full, EvalOutcome::Ok(_)),
            "guard-namespaced record leaked into the full pipeline: {full:?}"
        );
        assert!(ev.runtime_stats().unwrap().executions > 0);
        // And the guarded view still rejects after the full record
        // landed under the normal key.
        let mut rng = Rng::new(2);
        assert!(matches!(
            ev.evaluate_guarded(&src, &task, "-", &mut rng),
            EvalOutcome::GuardReject { .. }
        ));
        diagnostics
    };

    // The diagnostics that replayed are exactly the ones journaled.
    let report = guard::check_source(&src, &task);
    assert_eq!(first_diags, report.diagnostics);

    std::fs::remove_dir_all(dir).ok();
}

/// A guarded + repaired optimization run replays bit-identically from
/// the persistent cache: same records, zero live PJRT work on the
/// second leg.
#[test]
fn repair_loop_cache_replay_is_bit_identical() {
    let reg = registry();
    let dir = tmpdir("replay");
    let cache = dir.join("cache.jsonl");

    let task = reg.get("cumsum_rows_64").unwrap().clone();
    let archive = Archive::new();
    let provider = SimProvider::new();
    let run = |store: Arc<EvalStore>| {
        let ev = Evaluator::new(reg.clone(), Runtime::new().unwrap()).with_store(store);
        let ctx = RunCtx {
            evaluator: &ev,
            task: &task,
            model: &MODELS[0],
            seed: 3,
            archive: &archive,
            provider: &provider,
            budget: 25,
            repair: RepairPolicy::Repair { max_attempts: 2 },
            feedback: Default::default(),
            bank: None,
            warm: None,
        };
        let rec = EvoEngineer::new(EvoVariant::Free).run(&ctx).unwrap();
        (rec, ev.runtime_stats().unwrap().executions)
    };

    let (cold, cold_exec) = run(EvalStore::open(&cache).unwrap());
    assert!(cold_exec > 0, "cold run must verify functionally on PJRT");

    let (warm, warm_exec) = run(EvalStore::open(&cache).unwrap());
    assert_eq!(
        cold.to_json().to_string(),
        warm.to_json().to_string(),
        "guarded+repaired replay diverged from the cold run"
    );
    assert_eq!(
        warm_exec, 0,
        "warm replay performed live PJRT executions ({warm_exec})"
    );

    std::fs::remove_dir_all(dir).ok();
}
