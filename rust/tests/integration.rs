//! Integration tests across modules: the full evaluation pipeline on
//! real artifacts, method runs, campaign slices, metrics and reports —
//! the cross-module counterpart of the per-module unit tests.

use std::path::PathBuf;
use std::sync::Arc;

use evoengineer::campaign::{self, results, CampaignConfig};
use evoengineer::costmodel::baseline_schedule;
use evoengineer::dsl::{self, KernelSpec};
use evoengineer::evals::{EvalOutcome, Evaluator};
use evoengineer::llm::{self, SimProvider, MODELS};
use evoengineer::methods::{self, Archive, RepairPolicy, RunCtx};
use evoengineer::metrics;
use evoengineer::report;
use evoengineer::runtime::Runtime;
use evoengineer::tasks::TaskRegistry;
use evoengineer::traverse::prompt::render;
use evoengineer::traverse::{Guidance, GuidanceConfig};
use evoengineer::util::Rng;

fn evaluator() -> Evaluator {
    let reg = Arc::new(
        TaskRegistry::load(PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")).unwrap(),
    );
    Evaluator::new(reg, Runtime::new().unwrap())
}

#[test]
fn evaluation_pipeline_end_to_end() {
    let ev = evaluator();
    let task = ev.registry.get("softmax_64").unwrap().clone();
    let mut rng = Rng::new(1);

    // Correct kernel: passes both gates, gets perf numbers.
    let spec = KernelSpec {
        op: task.name.clone(),
        semantics: "opt".into(),
        schedule: baseline_schedule(&task),
    };
    match ev.evaluate(&dsl::print(&spec), &task, &mut rng) {
        EvalOutcome::Ok(s) => {
            assert!(s.time > 0.0);
            assert!(s.true_speedup > 0.5);
        }
        other => panic!("expected Ok, got {other:?}"),
    }

    // Semantic bug: compiles, fails functional testing on live PJRT.
    let mut bug = spec.clone();
    bug.semantics = "bug_offset".into();
    match ev.evaluate(&dsl::print(&bug), &task, &mut rng) {
        EvalOutcome::FunctionalFail { max_abs_diff } => assert!(max_abs_diff > 1e-3),
        other => panic!("expected FunctionalFail, got {other:?}"),
    }

    // Hallucinated variant: rejected at lowering.
    let mut hall = spec.clone();
    hall.semantics = "turbo_v9".into();
    assert!(matches!(
        ev.evaluate(&dsl::print(&hall), &task, &mut rng),
        EvalOutcome::CompileFail { .. }
    ));

    // Syntax garbage: rejected by the front-end.
    assert!(matches!(
        ev.evaluate("__global__ void k() {}", &task, &mut rng),
        EvalOutcome::CompileFail { .. }
    ));
}

#[test]
fn functional_verdicts_hold_for_all_categories() {
    // One op per category: the opt (Pallas) artifact must match ref,
    // both bug artifacts must be caught — live PJRT numerics.
    let ev = evaluator();
    for op_name in [
        "matmul_32",
        "conv1d_k3_c8",
        "relu_64",
        "softmax_64",
        "mse_64",
        "cumsum_rows_64",
    ] {
        let task = ev.registry.get(op_name).unwrap().clone();
        assert!(ev.functional(&task, "opt").unwrap().pass, "{op_name}/opt");
        assert!(!ev.functional(&task, "bug_scale").unwrap().pass, "{op_name}/bug_scale");
        assert!(!ev.functional(&task, "bug_offset").unwrap().pass, "{op_name}/bug_offset");
    }
}

#[test]
fn prompt_to_llm_loop_respects_information() {
    // Render a real prompt for a real task, feed it to the SimLLM, and
    // check the emitted program targets the right op.
    let ev = evaluator();
    let task = ev.registry.get("gelu_big").unwrap().clone();
    let g = Guidance {
        task: &task,
        baseline_us: ev.baseline_time(&task) * 1e6,
        parent: None,
        history: vec![],
        insights: vec![],
        profiling: None,
        instruction: "Design a new kernel from scratch.".into(),
    };
    let prompt = render(&GuidanceConfig::free(), &g);
    let mut ok = 0;
    for seed in 0..30 {
        let mut rng = Rng::new(seed);
        let resp = llm::generate(&prompt, &MODELS[2], &mut rng);
        if let Ok(spec) = dsl::parse(&resp.text) {
            assert_eq!(spec.op, "gelu_big");
            ok += 1;
        }
    }
    assert!(ok >= 20, "{ok}/30 parsed");
}

#[test]
fn all_methods_run_on_all_categories() {
    let ev = evaluator();
    let archive = Archive::new();
    let provider = SimProvider::new();
    for method in methods::all_methods() {
        for op_name in ["matmul_32", "cumsum_rows_64"] {
            let task = ev.registry.get(op_name).unwrap().clone();
            let ctx = RunCtx {
                evaluator: &ev,
                task: &task,
                model: &MODELS[0],
                seed: 11,
                archive: &archive,
                provider: &provider,
                budget: 12,
                repair: RepairPolicy::Off,
                feedback: Default::default(),
                bank: None,
                warm: None,
            };
            let rec = method.run(&ctx).unwrap();
            assert!(rec.trials <= 12, "{}", method.name());
            assert!(rec.best_speedup >= 1.0);
            assert_eq!(rec.op, op_name);
        }
    }
    // Every method published its best kernels to the shared archive.
    assert!(archive.len() >= 1);
}

#[test]
fn campaign_slice_is_deterministic_and_reportable() {
    let cfg = CampaignConfig {
        methods: vec!["evoengineer-free".into(), "funsearch".into()],
        models: vec!["gpt".into()],
        seeds: vec![0, 1],
        max_ops: 6,
        budget: 10,
        quiet: true,
        ..CampaignConfig::default()
    };
    let a = campaign::run(&cfg, evaluator()).unwrap();
    let b = campaign::run(&cfg, evaluator()).unwrap();
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.op, y.op);
        assert_eq!(x.best_speedup, y.best_speedup, "{} {}", x.op, x.method);
        assert_eq!(x.prompt_tokens, y.prompt_tokens);
    }

    // Records survive a JSONL round-trip and feed every report.
    let dir = std::env::temp_dir().join(format!("evo_it_{}", std::process::id()));
    let path = dir.join("r.jsonl");
    results::save(&path, &a).unwrap();
    let back = results::load(&path).unwrap();
    assert_eq!(back.len(), a.len());
    for text in [
        report::table4(&back),
        report::fig1(&back),
        report::fig4(&back, ""),
        report::fig5(&back),
        report::table7(&back),
        report::fig8(&back),
    ] {
        assert!(!text.is_empty());
    }
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn validity_ordering_matches_the_paper() {
    // The paper's core claim at the trial level: Full > Insight > Free
    // on functional-correctness Pass@1 (Table 4's Validity block).
    let cfg = CampaignConfig {
        methods: vec![
            "evoengineer-free".into(),
            "evoengineer-insight".into(),
            "evoengineer-full".into(),
        ],
        models: vec!["gpt".into()],
        seeds: vec![0, 1],
        max_ops: 16,
        quiet: true,
        ..CampaignConfig::default()
    };
    let records = campaign::run(&cfg, evaluator()).unwrap();
    let rate = |m: &str| {
        let recs: Vec<&methods::KernelRunRecord> =
            records.iter().filter(|r| r.method.contains(m)).collect();
        let trials: usize = recs.iter().map(|r| r.trials).sum();
        let correct: usize = recs.iter().map(|r| r.correct_trials).sum();
        correct as f64 / trials as f64
    };
    let (free, insight, full) = (rate("Free"), rate("Insight"), rate("Full"));
    assert!(full > insight, "full={full:.3} insight={insight:.3}");
    assert!(insight > free, "insight={insight:.3} free={free:.3}");
}

#[test]
fn guarded_campaign_reports_stage_breakdown() {
    // A campaign slice under the repair policy: every record carries
    // the ablation label, the stage-0 machinery fires, and the
    // validity report breaks trials out per stage.
    let cfg = CampaignConfig {
        methods: vec!["evoengineer-free".into()],
        models: vec!["gpt".into()],
        seeds: vec![0],
        max_ops: 4,
        budget: 15,
        repair: methods::RepairPolicy::Repair { max_attempts: 2 },
        quiet: true,
        ..CampaignConfig::default()
    };
    let records = campaign::run(&cfg, evaluator()).unwrap();
    assert_eq!(records.len(), 4);
    assert!(records.iter().all(|r| r.repair_policy == "repair:2"));
    assert!(records.iter().all(|r| r.trials <= 15));
    assert!(
        records.iter().any(|r| r.repair_attempts > 0),
        "no repair calls fired across 4 ops x 15 trials"
    );
    let text = report::validity(&records);
    assert!(text.contains("Stage-0 rejected %"), "{text}");
    assert!(text.contains("repair policy: repair:2"), "{text}");

    // Stage-0 bookkeeping survives the records JSONL round-trip.
    let dir = std::env::temp_dir().join(format!("evo_guard_it_{}", std::process::id()));
    let path = dir.join("r.jsonl");
    results::save(&path, &records).unwrap();
    let back = results::load(&path).unwrap();
    for (a, b) in records.iter().zip(&back) {
        assert_eq!(a.guard_rejected_trials, b.guard_rejected_trials);
        assert_eq!(a.repaired_trials, b.repaired_trials);
        assert_eq!(a.repair_attempts, b.repair_attempts);
        assert_eq!(a.repair_policy, b.repair_policy);
    }
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn token_ordering_matches_figure4() {
    let ev = evaluator();
    let archive = Archive::new();
    let provider = SimProvider::new();
    let task = ev.registry.get("matmul_64").unwrap().clone();
    let tokens = |name: &str| {
        let ctx = RunCtx {
            evaluator: &ev,
            task: &task,
            model: &MODELS[0],
            seed: 0,
            archive: &archive,
            provider: &provider,
            budget: 30,
            repair: RepairPolicy::Off,
            feedback: Default::default(),
            bank: None,
            warm: None,
        };
        let rec = methods::by_name(name).unwrap().run(&ctx).unwrap();
        rec.total_tokens()
    };
    let free = tokens("evoengineer-free");
    let full = tokens("evoengineer-full");
    let aicuda = tokens("ai cuda");
    assert!(free < full, "free={free} full={full}");
    assert!(full < aicuda, "full={full} aicuda={aicuda}");
}

#[test]
fn metrics_pipeline_from_real_records() {
    let cfg = CampaignConfig {
        methods: vec!["ai cuda".into()],
        models: vec!["deepseek".into()],
        seeds: vec![0, 1],
        max_ops: 8,
        budget: 15,
        quiet: true,
        ..CampaignConfig::default()
    };
    let records = campaign::run(&cfg, evaluator()).unwrap();
    let summary = metrics::replication_summary(&records, "AI CUDA Engineer");
    assert_eq!(summary.n_ops, 8);
    assert!(summary.median_speedup_all.is_finite());
    let (xs, ys) = metrics::replication_pairs(&records, "AI CUDA Engineer", 0, 1);
    assert_eq!(xs.len(), 8);
    assert_eq!(ys.len(), 8);
}
