//! Journal torture tests (DESIGN.md §14): randomized truncation and
//! interior corruption against all three append-only JSONL stores —
//! the eval cache, the transcript journal, and the trial-event journal
//! — with the sidecar offset index on (`IndexMode::Auto`) and off
//! (`IndexMode::Off`).
//!
//! The contract under torture:
//!
//! * a journal truncated at ANY byte offset (a SIGKILL mid-write)
//!   reopens cleanly: the torn final line is repaired away, and every
//!   record that was fully flushed before the tear survives with
//!   byte-identical content;
//! * an interior line corrupted in place is skipped (scan) or dropped
//!   as a stale slot on first lookup (indexed) — either way the store
//!   serves identical lookup results in both modes;
//! * a sidecar gone stale (journal truncated or extended behind its
//!   back) is detected and rebuilt/extended, never trusted blindly;
//! * a repaired journal accepts fresh appends and round-trips them.
//!
//! Artifact-free: everything here runs without the compiled-op
//! registry, so the suite torture-tests the persistence layer on any
//! machine. Corruption bytes are ASCII-printable on purpose — the
//! JSONL readers treat invalid UTF-8 as an IO error, which is a
//! different failure mode than the per-line skip exercised here.

use std::path::{Path, PathBuf};

use evoengineer::costmodel::{BoundKind, Timing};
use evoengineer::guard::{GuardCode, GuardDiagnostic};
use evoengineer::store::events::{
    completed_trials, completed_trials_at, EventJournal, TrialEvent, TrialEventKind,
};
use evoengineer::store::index;
use evoengineer::store::{
    EvalKey, EvalStore, IndexMode, StoredEval, StoredOutcome, TranscriptEntry, TranscriptStore,
};
use evoengineer::util::Rng;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("evo_torture_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Write journal `bytes` to `dst`, dropping any sidecar at `dst`.
fn fresh_copy(dst: &Path, bytes: &[u8]) {
    index::delete_sidecar(dst);
    std::fs::write(dst, bytes).unwrap();
}

/// Number of complete `\n`-terminated lines in `bytes[..cut]` — the
/// records that must survive a reopen after truncating at `cut`.
fn whole_lines(bytes: &[u8], cut: usize) -> usize {
    bytes[..cut].iter().filter(|&&b| b == b'\n').count()
}

/// Byte offset where each line starts.
fn line_starts(bytes: &[u8]) -> Vec<usize> {
    let mut starts = vec![0usize];
    for (i, &b) in bytes.iter().enumerate() {
        if b == b'\n' && i + 1 < bytes.len() {
            starts.push(i + 1);
        }
    }
    starts
}

// ---------------------------------------------------------------- eval

/// Deterministic eval-cache fixture covering every outcome variant
/// (so torture exercises every serializer path), in insertion order.
fn eval_fixture(n: usize) -> Vec<(EvalKey, StoredEval)> {
    let ops = ["matmul_64", "relu_64", "softmax_256", "layernorm_64"];
    let mut out = Vec::new();
    for i in 0..n {
        let op = ops[i % ops.len()];
        let (key, outcome) = match i % 4 {
            0 => (
                EvalKey::from_canonical(op, &format!("canon {i}")),
                StoredOutcome::CompileFail { error: format!("line {i}: unexpected token `}}`") },
            ),
            1 => (
                EvalKey::from_canonical(op, &format!("canon {i}")),
                StoredOutcome::FunctionalFail { max_abs_diff: 0.125 + i as f64 * 0.001953125 },
            ),
            2 => (
                EvalKey::from_canonical(op, &format!("canon {i}")),
                StoredOutcome::Ok {
                    timing: Timing {
                        time: 1.5e-5 + i as f64 * 1e-7,
                        t_compute: 1.0e-5,
                        t_mem: 5.0e-6,
                        t_overhead: 5.0e-7,
                        traffic: 65536.0 + i as f64,
                        occupancy: 0.75,
                        eff_compute: 0.5,
                        eff_bw: 0.25,
                        launches: 1 + (i % 3) as u32,
                        bound: if i % 2 == 0 { BoundKind::Memory } else { BoundKind::Compute },
                    },
                },
            ),
            _ => (
                EvalKey::guarded(op, &format!("raw emission {i}")),
                StoredOutcome::GuardReject {
                    diagnostics: vec![GuardDiagnostic {
                        code: GuardCode::ShadowedBinding,
                        field: "vector_width".into(),
                        message: format!("assigned twice (case {i})"),
                        hint: Some(("vector_width".into(), "8".into())),
                    }],
                },
            ),
        };
        out.push((key, StoredEval { op: op.into(), model: "GPT-4.1".into(), outcome }));
    }
    out
}

/// Write the fixture to `path` (index off: pure journal bytes, no
/// sidecar side effects) and return the untorn reference bytes.
fn write_eval_journal(path: &Path, fixture: &[(EvalKey, StoredEval)]) -> Vec<u8> {
    std::fs::remove_file(path).ok();
    index::delete_sidecar(path);
    {
        let store = EvalStore::open_with(path, IndexMode::Off).unwrap();
        for (key, entry) in fixture {
            store.record(key, entry.clone()).unwrap();
        }
        store.flush().unwrap();
    }
    std::fs::read(path).unwrap()
}

/// Assert `store` holds exactly the first `n` fixture records, each
/// lookup-identical to the reference entry (Debug carries every field;
/// the serializers round-trip f64 exactly, so Debug equality is
/// content equality).
fn assert_eval_prefix(store: &EvalStore, fixture: &[(EvalKey, StoredEval)], n: usize) {
    assert_eq!(store.len(), n);
    for (i, (key, entry)) in fixture.iter().enumerate() {
        match store.lookup(key) {
            Some(got) if i < n => {
                assert_eq!(format!("{got:?}"), format!("{entry:?}"), "record {i} diverged")
            }
            None if i >= n => {}
            Some(_) => panic!("record {i} lies after the tear but was served"),
            None => panic!("record {i} lies before the tear but was lost"),
        }
    }
}

#[test]
fn eval_store_truncation_recovery_at_randomized_offsets() {
    let dir = tmpdir("eval_trunc");
    let master = dir.join("master.jsonl");
    let fixture = eval_fixture(120);
    let bytes = write_eval_journal(&master, &fixture);
    assert_eq!(whole_lines(&bytes, bytes.len()), fixture.len());

    let mut rng = Rng::new(0xE7);
    for t in 0..10u32 {
        let cut = 1 + rng.below(bytes.len() - 1);
        let survivors = whole_lines(&bytes, cut);
        let torn = &bytes[..cut];

        // Off: pure scan of the torn file.
        let off_path = dir.join(format!("off_{t}.jsonl"));
        fresh_copy(&off_path, torn);
        let store = EvalStore::open_with(&off_path, IndexMode::Off).unwrap();
        assert_eval_prefix(&store, &fixture, survivors);

        // Auto, no sidecar: first open scans, builds one, repairs.
        let auto_path = dir.join(format!("auto_{t}.jsonl"));
        fresh_copy(&auto_path, torn);
        let store = EvalStore::open_with(&auto_path, IndexMode::Auto).unwrap();
        assert_eval_prefix(&store, &fixture, survivors);
        drop(store);

        // Auto, STALE sidecar: prime an index on the untorn bytes,
        // then truncate the journal behind its back. The cover check
        // must reject it and fall back to a rebuild scan.
        let stale_path = dir.join(format!("stale_{t}.jsonl"));
        fresh_copy(&stale_path, &bytes);
        drop(EvalStore::open_with(&stale_path, IndexMode::Auto).unwrap());
        std::fs::write(&stale_path, torn).unwrap();
        let store = EvalStore::open_with(&stale_path, IndexMode::Auto).unwrap();
        assert_eval_prefix(&store, &fixture, survivors);

        // The repair must have truncated the file to whole lines.
        let repaired = std::fs::read(&off_path).unwrap();
        let keep = torn.iter().rposition(|&b| b == b'\n').map_or(0, |p| p + 1);
        assert_eq!(repaired, &torn[..keep], "repair must cut exactly the torn tail");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn eval_store_interior_corruption_agrees_across_modes() {
    let dir = tmpdir("eval_corrupt");
    let master = dir.join("master.jsonl");
    let fixture = eval_fixture(80);
    let bytes = write_eval_journal(&master, &fixture);
    let starts = line_starts(&bytes);
    assert_eq!(starts.len(), fixture.len());

    let mut rng = Rng::new(0x5EED);
    for t in 0..6u32 {
        // Smash the opening `{` of an interior (non-final) line with an
        // ASCII byte: the line is length-preserved but no longer JSON.
        let victim = rng.below(fixture.len() - 1);
        let mut corrupt = bytes.clone();
        corrupt[starts[victim]] = b'#';

        // Off: the scan skips the bad line; every other record served.
        let off_path = dir.join(format!("off_{t}.jsonl"));
        fresh_copy(&off_path, &corrupt);
        let off_store = EvalStore::open_with(&off_path, IndexMode::Off).unwrap();
        assert_eq!(off_store.len(), fixture.len() - 1);

        // Auto with a PRE-CORRUPTION sidecar: the cover tail (final
        // line) is intact, so the index validates and the open is
        // served by it — the corrupted record still has a slot. The
        // lookup must detect the stale slot and drop it, aligning the
        // observable behaviour with the scan path.
        let auto_path = dir.join(format!("auto_{t}.jsonl"));
        fresh_copy(&auto_path, &bytes);
        drop(EvalStore::open_with(&auto_path, IndexMode::Auto).unwrap());
        std::fs::write(&auto_path, &corrupt).unwrap();
        let auto_store = EvalStore::open_with(&auto_path, IndexMode::Auto).unwrap();
        assert!(auto_store.opened_indexed(), "intact cover tail must serve an indexed open");

        for (i, (key, entry)) in fixture.iter().enumerate() {
            let want = if i == victim { None } else { Some(format!("{entry:?}")) };
            let off_got = off_store.lookup(key).map(|e| format!("{e:?}"));
            let auto_got = auto_store.lookup(key).map(|e| format!("{e:?}"));
            assert_eq!(off_got, want, "scan lookup {i} (victim {victim})");
            assert_eq!(auto_got, want, "indexed lookup {i} (victim {victim})");
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn eval_store_sidecar_extends_after_foreign_appends() {
    let dir = tmpdir("eval_extend");
    let path = dir.join("cache.jsonl");
    let fixture = eval_fixture(40);
    let (first, rest) = fixture.split_at(25);
    write_eval_journal(&path, first);

    // Prime a sidecar covering the first 25 records.
    drop(EvalStore::open_with(&path, IndexMode::Auto).unwrap());
    assert!(index::health(&path).is_some(), "priming open must persist a sidecar");

    // Append the rest with indexing off — the sidecar goes stale but
    // its covered prefix stays valid.
    {
        let store = EvalStore::open_with(&path, IndexMode::Off).unwrap();
        for (key, entry) in rest {
            store.record(key, entry.clone()).unwrap();
        }
        store.flush().unwrap();
    }

    // Auto reopen: covered prefix validated, tail scanned, index
    // extended. Everything served; the NEXT open is indexed again.
    let store = EvalStore::open_with(&path, IndexMode::Auto).unwrap();
    assert_eval_prefix(&store, &fixture, fixture.len());
    drop(store);
    let store = EvalStore::open_with(&path, IndexMode::Auto).unwrap();
    assert!(store.opened_indexed(), "extended sidecar must serve the next open");
    assert_eval_prefix(&store, &fixture, fixture.len());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn eval_store_accepts_appends_after_repair() {
    let dir = tmpdir("eval_append");
    let path = dir.join("cache.jsonl");
    let fixture = eval_fixture(30);
    let bytes = write_eval_journal(&path, &fixture);

    // Tear mid-way through the final record.
    std::fs::write(&path, &bytes[..bytes.len() - 7]).unwrap();
    let survivors = fixture.len() - 1;

    for mode in [IndexMode::Auto, IndexMode::Off] {
        let fresh = eval_fixture(32); // 30..32 are new keys
        let (key, entry) = &fresh[31];
        {
            let store = EvalStore::open_with(&path, mode).unwrap();
            store.record(key, entry.clone()).unwrap();
            store.flush().unwrap();
        }
        let store = EvalStore::open_with(&path, mode).unwrap();
        assert_eq!(store.len(), survivors + 1);
        assert_eq!(
            store.lookup(key).map(|e| format!("{e:?}")),
            Some(format!("{entry:?}")),
            "post-repair append must round-trip (mode {mode:?})"
        );
        // Reset for the other mode: restore the torn journal.
        fresh_copy(&path, &bytes[..bytes.len() - 7]);
    }
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------- transcript

fn transcript_fixture(n: usize) -> Vec<(String, TranscriptEntry)> {
    (0..n)
        .map(|i| {
            (
                format!("{:064x}", 0xABCDu64 + i as u64), // sha256-hex-shaped keys
                TranscriptEntry {
                    role: if i % 3 == 0 { "repair" } else { "generate" }.into(),
                    model: "GPT-4.1".into(),
                    seed: u64::MAX - i as u64, // beyond f64-exact range
                    text: format!("kernel matmul_64 {{ semantics: opt; /* v{i} */ }}"),
                    insight: format!("widened loads (attempt {i})"),
                    prompt_tokens: 100 + i as u64,
                    completion_tokens: 40 + i as u64,
                },
            )
        })
        .collect()
}

fn write_transcript_journal(path: &Path, fixture: &[(String, TranscriptEntry)]) -> Vec<u8> {
    std::fs::remove_file(path).ok();
    index::delete_sidecar(path);
    {
        let t = TranscriptStore::open_with(path, IndexMode::Off).unwrap();
        t.record_source("sim").unwrap();
        for (key, entry) in fixture {
            t.append(key, entry.clone()).unwrap();
        }
        t.flush().unwrap();
    }
    std::fs::read(path).unwrap()
}

#[test]
fn transcript_truncation_recovery_at_randomized_offsets() {
    let dir = tmpdir("transcript_trunc");
    let master = dir.join("master.jsonl");
    let fixture = transcript_fixture(60);
    let bytes = write_transcript_journal(&master, &fixture);
    // Line 0 is the meta line; calls follow in order.
    assert_eq!(whole_lines(&bytes, bytes.len()), fixture.len() + 1);

    let mut rng = Rng::new(0x7A11);
    for t in 0..10u32 {
        let cut = 1 + rng.below(bytes.len() - 1);
        let lines = whole_lines(&bytes, cut);
        let calls = lines.saturating_sub(1);
        let torn = &bytes[..cut];

        for (mode, tag) in [(IndexMode::Off, "off"), (IndexMode::Auto, "auto")] {
            let path = dir.join(format!("{tag}_{t}.jsonl"));
            fresh_copy(&path, torn);
            if mode == IndexMode::Auto {
                // Prime a sidecar on the UNTORN bytes, then tear: the
                // stale cover must be rejected and rebuilt.
                std::fs::write(&path, &bytes).unwrap();
                drop(TranscriptStore::open_with(&path, IndexMode::Auto).unwrap());
                std::fs::write(&path, torn).unwrap();
            }
            let store = TranscriptStore::open_with(&path, mode).unwrap();
            assert_eq!(store.len(), calls, "{tag} cut at {cut}");
            assert_eq!(
                store.source().as_deref(),
                if lines >= 1 { Some("sim") } else { None },
                "{tag}: meta line survives iff the first line survives"
            );
            for (i, (key, entry)) in fixture.iter().enumerate() {
                match store.lookup(key) {
                    Some(got) if i < calls => assert_eq!(&got, entry, "{tag} call {i}"),
                    None if i >= calls => {}
                    Some(_) => panic!("{tag}: call {i} after the tear was served"),
                    None => panic!("{tag}: call {i} before the tear was lost"),
                }
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

// -------------------------------------------------------------- events

/// A three-cell event stream: cell "a" runs to RunFinished, cell "b"
/// is interrupted after two eval outcomes, cell "c" started only.
fn event_fixture() -> Vec<TrialEvent> {
    let mk = |op: &str, kind: TrialEventKind| TrialEvent {
        method: "EvoEngineer-Free (ours)".into(),
        model: "GPT-4.1".into(),
        op: op.into(),
        seed: 3,
        kind,
    };
    let eval = |op: &str, trial: usize| {
        mk(
            op,
            TrialEventKind::EvalOutcome {
                trial,
                outcome: "ok".into(),
                speedup: 1.0 + trial as f64 * 0.25,
                prompt_tokens: 120,
                completion_tokens: 40,
                src_hash: format!("{op}-hash-{trial}"),
            },
        )
    };
    let mut evs = Vec::new();
    evs.push(mk("matmul_64", TrialEventKind::RunStarted { budget: 4, provider: "sim".into() }));
    for trial in 0..3usize {
        evs.push(mk("matmul_64", TrialEventKind::TrialStarted { trial }));
        evs.push(mk(
            "matmul_64",
            TrialEventKind::GuardVerdict { trial, pass: true, diagnostics: 0 },
        ));
        evs.push(eval("matmul_64", trial));
        evs.push(mk("matmul_64", TrialEventKind::NewBest { trial, speedup: 1.5 }));
    }
    evs.push(mk(
        "matmul_64",
        TrialEventKind::RunFinished { trials: 3, best_speedup: 1.5, any_valid: true },
    ));
    evs.push(mk("relu_64", TrialEventKind::RunStarted { budget: 4, provider: "sim".into() }));
    evs.push(mk("relu_64", TrialEventKind::TrialStarted { trial: 0 }));
    evs.push(eval("relu_64", 0));
    evs.push(mk("relu_64", TrialEventKind::TrialStarted { trial: 1 }));
    evs.push(eval("relu_64", 1));
    evs.push(mk("softmax_256", TrialEventKind::RunStarted { budget: 4, provider: "sim".into() }));
    evs
}

#[test]
fn event_journal_truncation_recovery_and_resume_agreement() {
    let dir = tmpdir("events_trunc");
    let master = dir.join("master.jsonl");
    let fixture = event_fixture();
    std::fs::remove_file(&master).ok();
    index::delete_sidecar(&master);
    {
        let j = EventJournal::create(&master).unwrap();
        for ev in &fixture {
            j.append(ev).unwrap();
        }
        j.flush().unwrap();
    }
    let bytes = std::fs::read(&master).unwrap();
    assert_eq!(whole_lines(&bytes, bytes.len()), fixture.len());

    let mut rng = Rng::new(0xCAFE);
    let path = dir.join("torn.jsonl");
    for _ in 0..10u32 {
        let cut = 1 + rng.below(bytes.len() - 1);
        let survivors = whole_lines(&bytes, cut);
        let expect = &fixture[..survivors];

        // Prime a sidecar on the untorn journal (a previous resume
        // scan), then tear: the stale sidecar must be rebuilt.
        fresh_copy(&path, &bytes);
        let _ = completed_trials_at(&path, IndexMode::Auto).unwrap();
        std::fs::write(&path, &bytes[..cut]).unwrap();

        // Reopen repairs the torn tail; the full scan must read
        // exactly the surviving prefix.
        drop(EventJournal::open(&path).unwrap());
        let loaded = EventJournal::load(&path).unwrap();
        assert_eq!(loaded, expect, "cut at {cut}");

        // Trial-granular resume: indexed and scan paths fold the torn
        // journal to the same per-cell replay map as the in-memory
        // reference fold.
        let want = completed_trials(expect);
        let auto = completed_trials_at(&path, IndexMode::Auto).unwrap();
        let off = completed_trials_at(&path, IndexMode::Off).unwrap();
        assert_eq!(auto, want, "indexed resume scan, cut at {cut}");
        assert_eq!(off, want, "full resume scan, cut at {cut}");
        // And again, served by the now-rebuilt sidecar.
        let warm = completed_trials_at(&path, IndexMode::Auto).unwrap();
        assert_eq!(warm, want, "warm indexed resume scan, cut at {cut}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn event_journal_interior_corruption_agrees_across_modes() {
    let dir = tmpdir("events_corrupt");
    let path = dir.join("events.jsonl");
    let fixture = event_fixture();
    std::fs::remove_file(&path).ok();
    index::delete_sidecar(&path);
    {
        let j = EventJournal::create(&path).unwrap();
        for ev in &fixture {
            j.append(ev).unwrap();
        }
        j.flush().unwrap();
    }
    let bytes = std::fs::read(&path).unwrap();
    let starts = line_starts(&bytes);

    // Corrupt the relu_64 trial-1 EvalOutcome line (index 18 in the
    // fixture): the resume fold must lose exactly that pair, in both
    // modes, whether the sidecar predates the corruption or not.
    let victim = 18usize;
    assert!(matches!(fixture[victim].kind, TrialEventKind::EvalOutcome { trial: 1, .. }));
    let _ = completed_trials_at(&path, IndexMode::Auto).unwrap(); // prime sidecar
    let mut corrupt = bytes.clone();
    corrupt[starts[victim]] = b'#';
    std::fs::write(&path, &corrupt).unwrap();

    let mut surviving: Vec<TrialEvent> = fixture.clone();
    surviving.remove(victim);
    let want = completed_trials(&surviving);
    for mode in [IndexMode::Auto, IndexMode::Off, IndexMode::Auto] {
        let got = completed_trials_at(&path, mode).unwrap();
        assert_eq!(got, want, "mode {mode:?}");
    }
    let relu = ("EvoEngineer-Free (ours)".to_string(), "GPT-4.1".to_string(),
        "relu_64".to_string(), 3u64);
    assert_eq!(want[&relu], vec![(0usize, "relu_64-hash-0".to_string())]);
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------- bank

use evoengineer::bank::{self, BankEntry, KernelBank};

/// Deterministic bank fixture: distinct canonical sources per entry,
/// spread over four ops, with every provenance field populated.
fn bank_fixture(n: usize) -> Vec<BankEntry> {
    let ops = ["matmul_64", "relu_64", "softmax_256", "layernorm_64"];
    (0..n)
        .map(|i| {
            let op = ops[i % ops.len()];
            let src = format!("kernel {op} {{ semantics: opt; /* elite {i} */ }}");
            BankEntry {
                key: bank::entry_key(op, &src),
                op: op.into(),
                family: "ew".into(),
                category: 1 + (i % 6) as u8,
                goal: if i % 2 == 0 { "speedup" } else { "balanced" }.into(),
                src,
                speedup: 1.0 + i as f64 * 0.0625,
                rank: 1.0 + i as f64 * 0.0625,
                shape: vec![64, 64],
                profile: format!("memory-bound; occupancy 0.75 (case {i})"),
                provider: "sim".into(),
                model: "GPT-4.1".into(),
                method: "EvoEngineer-Full (ours)".into(),
                route: String::new(),
                insight: format!("widened loads (elite {i})"),
            }
        })
        .collect()
}

fn write_bank_journal(path: &Path, fixture: &[BankEntry]) -> Vec<u8> {
    std::fs::remove_file(path).ok();
    index::delete_sidecar(path);
    {
        let b = KernelBank::open_with(path, IndexMode::Off).unwrap();
        for e in fixture {
            assert!(b.deposit(e.clone()).unwrap());
        }
        b.flush().unwrap();
    }
    std::fs::read(path).unwrap()
}

#[test]
fn bank_truncation_recovery_and_dedup_backfill() {
    let dir = tmpdir("bank_trunc");
    let master = dir.join("master.jsonl");
    let fixture = bank_fixture(40);
    let bytes = write_bank_journal(&master, &fixture);
    assert_eq!(whole_lines(&bytes, bytes.len()), fixture.len());

    let mut rng = Rng::new(0xBA2C);
    for t in 0..8u32 {
        let cut = 1 + rng.below(bytes.len() - 1);
        let survivors = whole_lines(&bytes, cut);
        let torn = &bytes[..cut];

        for (mode, tag) in [(IndexMode::Off, "off"), (IndexMode::Auto, "auto")] {
            let path = dir.join(format!("{tag}_{t}.jsonl"));
            fresh_copy(&path, torn);
            if mode == IndexMode::Auto {
                // Prime a sidecar on the untorn bytes, then tear: the
                // stale cover must be rejected and rebuilt.
                std::fs::write(&path, &bytes).unwrap();
                drop(KernelBank::open_with(&path, IndexMode::Auto).unwrap());
                std::fs::write(&path, torn).unwrap();
            }
            let b = KernelBank::open_with(&path, mode).unwrap();
            assert_eq!(b.len(), survivors, "{tag} cut at {cut}");

            // Content-key dedup backfill: re-depositing the whole
            // fixture restores exactly the records the tear destroyed
            // and leaves the survivors' journal lines untouched.
            for e in &fixture {
                let fresh = b.deposit(e.clone()).unwrap();
                assert_eq!(
                    fresh,
                    fixture.iter().position(|f| f.key == e.key).unwrap() >= survivors,
                    "{tag}: dedup verdict wrong for {}",
                    e.key
                );
            }
            b.flush().unwrap();
            drop(b);
            let reopened = KernelBank::open_with(&path, mode).unwrap();
            assert_eq!(reopened.len(), fixture.len(), "{tag}: backfill incomplete");
            let mut entries = reopened.all_entries();
            entries.sort_by(|a, b| a.key.cmp(&b.key));
            let mut want = fixture.clone();
            want.sort_by(|a, b| a.key.cmp(&b.key));
            assert_eq!(entries, want, "{tag}: entry content diverged after repair");
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn bank_gc_collapses_duplicates_and_corruption() {
    let dir = tmpdir("bank_gc");
    let path = dir.join("bank.jsonl");
    let fixture = bank_fixture(12);
    let bytes = write_bank_journal(&path, &fixture);

    // Simulate two merged worker shards: append a full duplicate copy
    // of the journal plus one corrupt line.
    let mut doubled = bytes.clone();
    doubled.extend_from_slice(b"#corrupt line\n");
    doubled.extend_from_slice(&bytes);
    fresh_copy(&path, &doubled);

    let stats = bank::stats(&path).unwrap();
    assert_eq!(stats.entries, fixture.len());
    assert_eq!(stats.dup_lines, fixture.len());

    // First occurrence wins, corrupt line dropped; the compacted
    // journal is exactly the original bytes.
    let (before, after) = bank::gc(&path).unwrap();
    assert!(before > after);
    assert_eq!(std::fs::read(&path).unwrap(), bytes);
    let stats = bank::stats(&path).unwrap();
    assert_eq!((stats.entries, stats.dup_lines), (fixture.len(), 0));

    // export_lines collapses the same way without touching the file.
    fresh_copy(&path, &doubled);
    let exported = KernelBank::load(&path).unwrap().export_lines();
    assert_eq!(exported.len(), fixture.len());
    assert_eq!(std::fs::read(&path).unwrap(), doubled, "export must not mutate the journal");
    std::fs::remove_dir_all(&dir).ok();
}
