//! Kernel-bank determinism contract (DESIGN.md §18).
//!
//! The four byte-identity guarantees the bank must not break:
//!
//! 1. attaching a deposit bank never changes records or events;
//! 2. warm-starting from an *empty* bank is byte-identical to running
//!    cold (so the flag can default on without a determinism tax);
//! 3. a warm-started campaign is deterministic across runs;
//! 4. record-then-replay with `bank_refs` set replays bit-identically
//!    with zero live calls and leaves the bank journal's bytes
//!    untouched (the replay re-derives the same elites, which dedup
//!    away on their content keys).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use evoengineer::campaign::{self, CampaignConfig};
use evoengineer::evals::Evaluator;
use evoengineer::llm::ProviderSpec;
use evoengineer::methods::KernelRunRecord;
use evoengineer::runtime::Runtime;
use evoengineer::tasks::TaskRegistry;

fn evaluator() -> Evaluator {
    let reg = Arc::new(
        TaskRegistry::load(PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")).unwrap(),
    );
    Evaluator::new(reg, Runtime::new().unwrap())
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "evo_bank_it_{tag}_{}_{}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .subsec_nanos()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// A small slice with enough room for new-best deposits: two
/// archive-hungry methods, one op, a double-digit budget.
fn base_cfg() -> CampaignConfig {
    CampaignConfig {
        methods: vec!["evoengineer-full".into(), "funsearch".into()],
        models: vec!["gpt".into()],
        seeds: vec![0],
        op_filter: "relu_64".into(),
        budget: 10,
        quiet: true,
        concurrency: 1,
        ..CampaignConfig::default()
    }
}

fn assert_identical(a: &[KernelRunRecord], b: &[KernelRunRecord], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: record count diverged");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(
            x.to_json().to_string(),
            y.to_json().to_string(),
            "{what}: record diverged for {}/{}",
            x.method,
            x.op
        );
    }
}

/// Run the slice cold, depositing into `bank`, with events at `events`.
fn run_with(
    bank: Option<&Path>,
    warm: Option<&Path>,
    events: &Path,
) -> Vec<KernelRunRecord> {
    let cfg = CampaignConfig {
        bank: bank.map(Path::to_path_buf),
        warm_start: warm.map(Path::to_path_buf),
        events: Some(events.to_path_buf()),
        ..base_cfg()
    };
    campaign::run(&cfg, evaluator()).unwrap()
}

#[test]
fn deposit_bank_never_changes_records_or_events() {
    let dir = tmpdir("deposit");
    let bank = dir.join("bank.jsonl");

    let off = run_with(None, None, &dir.join("ev_off.jsonl"));
    let on = run_with(Some(&bank), None, &dir.join("ev_on.jsonl"));

    assert_identical(&off, &on, "bank-on vs bank-off");
    assert_eq!(
        std::fs::read(dir.join("ev_off.jsonl")).unwrap(),
        std::fs::read(dir.join("ev_on.jsonl")).unwrap(),
        "event journal changed when a deposit bank was attached"
    );

    // The side-write really happened: elites for the op are journaled
    // with their provenance, retrievable and canonical.
    let stats = evoengineer::bank::stats(&bank).unwrap();
    assert!(stats.entries > 0, "no elites deposited across 2 cells x 10 trials");
    assert!(stats.per_op.iter().any(|(op, ..)| op == "relu_64"), "{stats:?}");
    let loaded = evoengineer::bank::KernelBank::load(&bank).unwrap();
    for e in loaded.all_entries() {
        assert_eq!(e.op, "relu_64");
        assert!(e.speedup > 0.0, "deposited elite has no measured speedup");
        assert!(!e.method.is_empty() && !e.model.is_empty() && !e.provider.is_empty());
        assert_eq!(e.key, evoengineer::bank::entry_key(&e.op, &e.src));
    }
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn empty_warm_bank_is_byte_identical_to_cold() {
    let dir = tmpdir("empty_warm");
    let empty = dir.join("empty_bank.jsonl");
    std::fs::write(&empty, b"").unwrap();

    let cold = run_with(None, None, &dir.join("ev_cold.jsonl"));
    let warm = run_with(None, Some(&empty), &dir.join("ev_warm.jsonl"));

    assert_identical(&cold, &warm, "cold vs empty-warm");
    assert_eq!(
        std::fs::read(dir.join("ev_cold.jsonl")).unwrap(),
        std::fs::read(dir.join("ev_warm.jsonl")).unwrap(),
        "an empty warm-start snapshot perturbed the event stream"
    );
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn warm_started_campaign_is_deterministic() {
    let dir = tmpdir("warm_det");
    let bank = dir.join("bank.jsonl");

    // Seed the bank from a cold pass, then run the warm slice twice.
    run_with(Some(&bank), None, &dir.join("ev_seed.jsonl"));
    let a = run_with(None, Some(&bank), &dir.join("ev_a.jsonl"));
    let b = run_with(None, Some(&bank), &dir.join("ev_b.jsonl"));

    assert_identical(&a, &b, "warm run A vs warm run B");
    assert_eq!(
        std::fs::read(dir.join("ev_a.jsonl")).unwrap(),
        std::fs::read(dir.join("ev_b.jsonl")).unwrap(),
        "warm-started event journals diverged across identical runs"
    );
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn replay_with_bank_refs_leaves_the_bank_untouched() {
    let dir = tmpdir("replay");
    let seed_bank = dir.join("seed_bank.jsonl");
    let deposit_bank = dir.join("deposit_bank.jsonl");
    let transcripts = dir.join("transcripts.jsonl");

    // Pass 1 (cold): fill the snapshot bank.
    run_with(Some(&seed_bank), None, &dir.join("ev_seed.jsonl"));
    assert!(evoengineer::bank::stats(&seed_bank).unwrap().entries > 0);

    // Pass 2 (record): warm-started — so every generation request
    // carries a `## PRIOR ELITES` section and its hash covers the
    // `bank_refs` field — live generation recorded to the transcript
    // journal, new elites deposited.
    let record_cfg = CampaignConfig {
        bank: Some(deposit_bank.clone()),
        warm_start: Some(seed_bank.clone()),
        transcripts: Some(transcripts.clone()),
        events: Some(dir.join("ev_record.jsonl")),
        ..base_cfg()
    };
    let recorded = campaign::run(&record_cfg, evaluator()).unwrap();
    let bank_bytes = std::fs::read(&deposit_bank).unwrap();
    assert!(!bank_bytes.is_empty(), "warm-started pass deposited nothing");

    // Pass 3 (replay): zero live calls — every request hash (including
    // the bank_refs extension) must hit the journal — and the replay
    // re-derives the same elites, which dedup to zero new journal
    // lines.
    let replay_cfg = CampaignConfig {
        bank: Some(deposit_bank.clone()),
        warm_start: Some(seed_bank.clone()),
        provider: ProviderSpec::Replay(transcripts),
        events: Some(dir.join("ev_replay.jsonl")),
        ..base_cfg()
    };
    let replayed = campaign::run(&replay_cfg, evaluator()).unwrap();

    assert_identical(&recorded, &replayed, "record vs replay");
    assert_eq!(
        std::fs::read(dir.join("ev_record.jsonl")).unwrap(),
        std::fs::read(dir.join("ev_replay.jsonl")).unwrap(),
        "replay event journal diverged from the recording"
    );
    assert_eq!(
        std::fs::read(&deposit_bank).unwrap(),
        bank_bytes,
        "replay grew or rewrote the bank journal"
    );
    std::fs::remove_dir_all(dir).ok();
}
