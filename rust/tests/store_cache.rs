//! Persistent evaluation cache + campaign checkpoint/resume
//! (DESIGN.md §8): hash stability, replay bit-identity, cross-method
//! deduplication, and the kill-and-resume guarantee — a campaign
//! interrupted mid-sweep and resumed must produce byte-identical
//! records and reports to an uninterrupted run.

use std::path::PathBuf;
use std::sync::Arc;

use evoengineer::campaign::{self, CampaignConfig};
use evoengineer::costmodel::baseline_schedule;
use evoengineer::dsl::{self, KernelSpec};
use evoengineer::evals::{EvalOutcome, Evaluator};
use evoengineer::report;
use evoengineer::runtime::Runtime;
use evoengineer::store::{key_for_source, EvalStore};
use evoengineer::tasks::TaskRegistry;
use evoengineer::util::Rng;

fn registry() -> Arc<TaskRegistry> {
    Arc::new(
        TaskRegistry::load(PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")).unwrap(),
    )
}

fn evaluator() -> Evaluator {
    Evaluator::new(registry(), Runtime::new().unwrap())
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("evo_cache_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn baseline_src(op: &str, reg: &TaskRegistry) -> String {
    let task = reg.get(op).unwrap();
    dsl::print(&KernelSpec {
        op: task.name.clone(),
        semantics: "opt".into(),
        schedule: baseline_schedule(task),
    })
}

#[test]
fn key_stable_under_whitespace_and_reprint() {
    // No artifacts needed: keying is parse → canonical print → hash.
    let spec = KernelSpec::baseline("matmul_64");
    let src = dsl::print(&spec);
    let reprinted = dsl::print(&dsl::parse(&src).unwrap());
    let noisy = format!("  {}\n\n# trailing comment\n", src.replace("; ", " ;\n   "));
    assert_ne!(src, noisy);
    let k = key_for_source("matmul_64", &src).unwrap();
    assert_eq!(k, key_for_source("matmul_64", &reprinted).unwrap());
    assert_eq!(k, key_for_source("matmul_64", &noisy).unwrap());

    // Any semantic or schedule change moves the key.
    let mut other = spec.clone();
    other.schedule.vector_width = spec.schedule.vector_width * 2;
    assert_ne!(
        k,
        key_for_source("matmul_64", &dsl::print(&other)).unwrap()
    );
    let mut bug = spec;
    bug.semantics = "bug_scale".into();
    assert_ne!(k, key_for_source("matmul_64", &dsl::print(&bug)).unwrap());
}

/// Field-exact equality for outcomes (EvalOutcome has no PartialEq —
/// Timing carries floats we want compared bit-for-bit here).
fn assert_outcome_identical(a: &EvalOutcome, b: &EvalOutcome) {
    match (a, b) {
        (EvalOutcome::Ok(x), EvalOutcome::Ok(y)) => {
            assert_eq!(x.time, y.time);
            assert_eq!(x.speedup, y.speedup);
            assert_eq!(x.pytorch_speedup, y.pytorch_speedup);
            assert_eq!(x.true_speedup, y.true_speedup);
            assert_eq!(x.true_pytorch_speedup, y.true_pytorch_speedup);
            assert_eq!(x.timing.time, y.timing.time);
            assert_eq!(x.timing.occupancy, y.timing.occupancy);
            assert_eq!(x.timing.launches, y.timing.launches);
        }
        (
            EvalOutcome::CompileFail { error: ea },
            EvalOutcome::CompileFail { error: eb },
        ) => assert_eq!(ea, eb),
        (
            EvalOutcome::FunctionalFail { max_abs_diff: da },
            EvalOutcome::FunctionalFail { max_abs_diff: db },
        ) => assert_eq!(da, db),
        (x, y) => panic!("outcome kinds differ: {x:?} vs {y:?}"),
    }
}

#[test]
fn replay_is_bit_identical_to_cold_evaluation() {
    let dir = tmpdir("replay");
    let cache = dir.join("cache.jsonl");
    let reg = registry();
    let task = reg.get("softmax_64").unwrap().clone();
    let src = baseline_src("softmax_64", &reg);
    let garbage = "kernel softmax_64 { semantics opt }"; // parse error
    let mut bug = dsl::parse(&src).unwrap();
    bug.semantics = "bug_offset".into();
    let bug_src = dsl::print(&bug);

    // Ground truth: a plain evaluator with no persistent cache.
    let plain = evaluator();
    let eval_plain = |s: &str| {
        let mut rng = Rng::new(7).derive("replay-test");
        plain.evaluate(s, &task, &mut rng)
    };

    // Leg 1 populates the journal (cold misses)…
    {
        let ev = evaluator().with_store(EvalStore::open(&cache).unwrap());
        for s in [src.as_str(), bug_src.as_str(), garbage] {
            let mut rng = Rng::new(7).derive("replay-test");
            assert_outcome_identical(&eval_plain(s), &ev.evaluate(s, &task, &mut rng));
        }
        let store = ev.store().unwrap();
        assert_eq!(store.len(), 2, "garbage must not be journaled");
        assert_eq!(store.hits(), 0);
    }
    // …leg 2 is a fresh process: everything replays from disk,
    // bit-identical under the same RNG stream.
    {
        let ev = evaluator().with_store(EvalStore::open(&cache).unwrap());
        for s in [src.as_str(), bug_src.as_str()] {
            let mut rng = Rng::new(7).derive("replay-test");
            assert_outcome_identical(&eval_plain(s), &ev.evaluate(s, &task, &mut rng));
        }
        assert_eq!(ev.store().unwrap().hits(), 2);
        assert_eq!(ev.store().unwrap().misses(), 0);
    }
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn cross_method_dedup_evaluates_once() {
    let dir = tmpdir("dedup");
    let cache = dir.join("cache.jsonl");
    let reg = registry();
    let task = reg.get("relu_64").unwrap().clone();
    let src = baseline_src("relu_64", &reg);
    let noisy = src.replace("; ", ";  "); // different text, same kernel

    let ev = evaluator().with_store(EvalStore::open(&cache).unwrap());
    // The same candidate arriving from different methods/models/texts:
    // one real evaluation, the rest served from the store.
    let mut rng = Rng::new(1);
    ev.evaluate_keyed(&src, &task, "GPT-4.1", &mut rng);
    ev.evaluate_keyed(&noisy, &task, "Claude-Sonnet-4", &mut rng);
    ev.evaluate_keyed(&src, &task, "DeepSeek-V3.1", &mut rng);
    let store = ev.store().unwrap();
    assert_eq!(store.len(), 1, "identical candidates must share one entry");
    assert_eq!(store.misses(), 1);
    assert_eq!(store.hits(), 2);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn killed_campaign_resumes_to_identical_report() {
    let dir = tmpdir("resume");
    let checkpoint = dir.join("records.jsonl.checkpoint.jsonl");
    let cache = dir.join("eval_cache.jsonl");
    // Methods that do not read the cross-op archive (aicuda's RAG is
    // scheduling-dependent); everything else is deterministic per cell.
    let base = CampaignConfig {
        methods: vec!["evoengineer-free".into(), "funsearch".into()],
        models: vec!["gpt".into()],
        seeds: vec![0, 1],
        max_ops: 2,
        budget: 4,
        quiet: true,
        ..CampaignConfig::default()
    };

    // Reference: one uninterrupted run, no checkpoint, no cache.
    let full = campaign::run(&base, evaluator()).unwrap();
    assert_eq!(full.len(), 8);

    // Leg 1: same sweep, checkpointed + cached, killed after 3 cells.
    // --stop-after is claim-gated, so exactly 3 cells complete — the
    // old completion-count check raced with in-flight workers and
    // could let extra cells slip through.
    let leg1_cfg = CampaignConfig {
        checkpoint: Some(checkpoint.clone()),
        stop_after: 3,
        concurrency: 1,
        ..base.clone()
    };
    let ev1 = evaluator().with_store(EvalStore::open(&cache).unwrap());
    let partial = campaign::run(&leg1_cfg, ev1).unwrap();
    assert_eq!(partial.len(), 3, "claim-gated stop_after must complete exactly 3 cells");

    // Harden the kill simulation: a real SIGKILL can tear the final
    // journal line mid-write. Resume must repair, not trip over it.
    {
        use std::io::Write as _;
        let mut f = std::fs::OpenOptions::new().append(true).open(&checkpoint).unwrap();
        write!(f, "{{\"method\":\"Evo").unwrap();
    }

    // Leg 2: resume. Must complete the grid and match the reference
    // byte for byte, with warm cache hits on the second leg.
    let leg2_cfg = CampaignConfig {
        checkpoint: Some(checkpoint.clone()),
        resume: true,
        ..base.clone()
    };
    let ev2 = evaluator().with_store(EvalStore::open(&cache).unwrap());
    let store2 = ev2.store().unwrap().clone();
    let resumed = campaign::run(&leg2_cfg, ev2).unwrap();
    assert_eq!(resumed.len(), full.len());
    for (a, b) in full.iter().zip(&resumed) {
        assert_eq!(
            a.to_json().to_string(),
            b.to_json().to_string(),
            "resumed record differs for {}/{}/{}/{}",
            a.method,
            a.model,
            a.op,
            a.seed
        );
    }
    assert_eq!(report::table4(&full), report::table4(&resumed));
    assert_eq!(report::fig1(&full), report::fig1(&resumed));
    assert!(
        store2.hits() > 0,
        "second leg must be served warm candidates from the first"
    );
    // `cache stats` sees the journaled session counters.
    let stats = EvalStore::stats(&cache).unwrap();
    assert!(stats.hits >= store2.hits());
    assert!(stats.entries > 0);

    // Resuming a *finished* campaign runs nothing and still reports
    // identically (all cells come from the journal).
    let ev3 = evaluator();
    let replayed = campaign::run(&leg2_cfg, ev3).unwrap();
    assert_eq!(report::table4(&full), report::table4(&replayed));

    // Resuming under a different --budget must re-run every cell
    // rather than silently merging mixed-budget records.
    let other_budget = CampaignConfig {
        budget: 3,
        checkpoint: Some(checkpoint.clone()),
        resume: true,
        ..base.clone()
    };
    let rerun = campaign::run(&other_budget, evaluator()).unwrap();
    assert_eq!(rerun.len(), full.len());
    assert!(rerun.iter().all(|r| r.budget == 3 && r.trials <= 3));
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn checkpoint_journal_feeds_reports_midway() {
    let dir = tmpdir("midreport");
    let checkpoint = dir.join("ckpt.jsonl");
    let cfg = CampaignConfig {
        methods: vec!["evoengineer-free".into()],
        models: vec!["gpt".into()],
        seeds: vec![0],
        max_ops: 2,
        budget: 3,
        quiet: true,
        checkpoint: Some(checkpoint.clone()),
        stop_after: 1,
        concurrency: 1,
        ..CampaignConfig::default()
    };
    campaign::run(&cfg, evaluator()).unwrap();
    // A partial journal renders like any records file.
    let partial = campaign::results::load_lenient(&checkpoint).unwrap();
    assert_eq!(partial.len(), 1);
    assert!(!report::table4(&partial).is_empty());
    assert!(!report::fig8(&partial).is_empty());
    std::fs::remove_dir_all(dir).ok();
}
