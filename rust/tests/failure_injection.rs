//! Failure-injection tests: every error path a long campaign can hit
//! must degrade gracefully (error values, never panics, owner threads
//! survive) — the robustness half of the evaluation pipeline.

use std::path::PathBuf;
use std::sync::Arc;

use evoengineer::evals::{EvalOutcome, Evaluator};
use evoengineer::methods::{Archive, ArchiveEntry};
use evoengineer::runtime::{Runtime, TensorValue};
use evoengineer::tasks::{ArgSpec, OpTask, TaskRegistry};
use evoengineer::util::Rng;

fn registry() -> TaskRegistry {
    TaskRegistry::load(PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")).unwrap()
}

fn tmpdir() -> PathBuf {
    let d = std::env::temp_dir().join(format!("evo_fail_{}_{}", std::process::id(), rand_tag()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn rand_tag() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap()
        .subsec_nanos() as u64
}

#[test]
fn corrupted_hlo_artifact_is_an_error() {
    let rt = Runtime::new().unwrap();
    let dir = tmpdir();
    let bad = dir.join("bad.hlo.txt");
    std::fs::write(&bad, "HloModule utter_garbage {{{{").unwrap();
    let err = rt.execute(bad, vec![]);
    assert!(err.is_err(), "garbage HLO must fail to compile");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn truncated_hlo_artifact_is_an_error_and_runtime_survives() {
    let reg = registry();
    let rt = Runtime::new().unwrap();
    // Truncate a real artifact halfway.
    let task = reg.get("relu_64").unwrap();
    let good_path = reg.artifact_path(task, "ref").unwrap();
    let text = std::fs::read_to_string(&good_path).unwrap();
    let dir = tmpdir();
    let bad = dir.join("truncated.hlo.txt");
    std::fs::write(&bad, &text[..text.len() / 2]).unwrap();
    assert!(rt.execute(bad, vec![]).is_err());

    // Owner thread must still serve good requests afterwards.
    let inputs = vec![TensorValue::new(vec![64, 64], vec![0.5; 64 * 64])];
    let out = rt.execute(good_path, inputs).unwrap();
    assert_eq!(out.len(), 64 * 64);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn wrong_input_arity_is_an_error() {
    let reg = registry();
    let rt = Runtime::new().unwrap();
    let task = reg.get("matmul_32").unwrap();
    // matmul takes two inputs; give one.
    let res = rt.execute(
        reg.artifact_path(task, "ref").unwrap(),
        vec![TensorValue::new(vec![32, 32], vec![1.0; 1024])],
    );
    assert!(res.is_err());
}

#[test]
fn evaluator_reports_runtime_fail_for_missing_artifact() {
    // An op whose manifest points at a nonexistent artifact file: the
    // evaluator must return RuntimeFail, not panic, and the campaign
    // convention treats it as a functional failure.
    let reg = registry();
    let mut task: OpTask = reg.get("relu_64").unwrap().clone();
    task.artifacts
        .insert("opt".into(), "does/not/exist.hlo.txt".into());
    let ev = Evaluator::new(Arc::new(reg), Runtime::new().unwrap());
    let src = "kernel relu_64 { semantics: opt; }";
    let mut rng = Rng::new(0);
    match ev.evaluate(src, &task, &mut rng) {
        EvalOutcome::RuntimeFail { error } => assert!(error.contains("exist")),
        other => panic!("expected RuntimeFail, got {other:?}"),
    }
}

#[test]
fn evaluator_memoizes_functional_verdicts() {
    let reg = Arc::new(registry());
    let ev = Evaluator::new(reg.clone(), Runtime::new().unwrap());
    let task = reg.get("sigmoid_64").unwrap().clone();
    ev.functional(&task, "opt").unwrap();
    let after_first = ev.runtime_stats().unwrap().executions;
    assert!(after_first > 0);
    // Second verdict for the same (op, variant): no new executions.
    ev.functional(&task, "opt").unwrap();
    assert_eq!(ev.runtime_stats().unwrap().executions, after_first);
    // Different variant: new executions happen.
    ev.functional(&task, "bug_scale").unwrap();
    assert!(ev.runtime_stats().unwrap().executions > after_first);
}

#[test]
fn baseline_time_is_memoized_and_positive() {
    let reg = Arc::new(registry());
    let ev = Evaluator::new(reg.clone(), Runtime::new().unwrap());
    for op in reg.ops.iter().take(12) {
        let t1 = ev.baseline_time(op);
        let t2 = ev.baseline_time(op);
        assert!(t1 > 0.0, "{}", op.name);
        assert_eq!(t1, t2, "{}", op.name);
    }
}

#[test]
fn archive_prefers_same_family_then_speedup() {
    let archive = Archive::new();
    for (op, family, speedup) in [
        ("a", "matmul", 5.0),
        ("b", "conv", 9.0),
        ("c", "matmul", 2.0),
        ("d", "loss", 7.0),
    ] {
        archive.record(ArchiveEntry {
            op: op.into(),
            family: family.into(),
            src: format!("kernel {op} {{ semantics: opt; }}"),
            speedup,
            rank: speedup,
        });
    }
    let similar = archive.similar("zzz", "matmul", 3);
    assert_eq!(similar.len(), 3);
    // Same-family entries first, best speedup first within family.
    assert_eq!(similar[0].op, "a");
    assert_eq!(similar[1].op, "c");
    assert_eq!(similar[2].op, "b"); // best of the rest
    // Self is excluded.
    assert!(archive.similar("a", "matmul", 5).iter().all(|e| e.op != "a"));
    // Re-recording with lower speedup does not overwrite.
    archive.record(ArchiveEntry {
        op: "a".into(),
        family: "matmul".into(),
        src: "worse".into(),
        speedup: 1.0,
        rank: 1.0,
    });
    assert_eq!(archive.similar("zzz", "matmul", 1)[0].speedup, 5.0);
}

#[test]
fn tensor_inputs_with_nan_still_produce_output() {
    // The evaluator never feeds NaNs, but the runtime must not wedge
    // if a future workload does.
    let reg = registry();
    let rt = Runtime::new().unwrap();
    let task = reg.get("relu_64").unwrap();
    let mut data = vec![0.25f32; 64 * 64];
    data[0] = f32::NAN;
    let out = rt
        .execute(
            reg.artifact_path(task, "ref").unwrap(),
            vec![TensorValue::new(vec![64, 64], data)],
        )
        .unwrap();
    assert!(out[0].is_nan());
    assert!(out[1..].iter().all(|x| x.is_finite()));
}

#[test]
fn zero_budget_run_is_well_formed() {
    let reg = Arc::new(registry());
    let ev = Evaluator::new(reg.clone(), Runtime::new().unwrap());
    let task = reg.get("matmul_32").unwrap().clone();
    let archive = Archive::new();
    let provider = evoengineer::llm::SimProvider::new();
    let ctx = evoengineer::methods::RunCtx {
        evaluator: &ev,
        task: &task,
        model: &evoengineer::llm::MODELS[0],
        seed: 0,
        archive: &archive,
        provider: &provider,
        budget: 0,
        repair: evoengineer::methods::RepairPolicy::Off,
        feedback: Default::default(),
        bank: None,
        warm: None,
    };
    for method in evoengineer::methods::all_methods() {
        let rec = method.run(&ctx).unwrap();
        assert_eq!(rec.trials, 0, "{}", method.name());
        assert_eq!(rec.best_speedup, 1.0);
        assert!(!rec.any_valid);
    }
}
