//! Table/figure regeneration benches — one end-to-end bench per paper
//! table and figure (DESIGN.md §6), plus the §7 ablations. Each bench
//! runs the slice of the campaign that feeds that artifact and renders
//! it, so `cargo bench --bench tables` both times and *prints* every
//! reproduced result (the bench output doubles as the reproduction
//! log captured in bench_output.txt).

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use evoengineer::campaign::{self, CampaignConfig};
use evoengineer::evals::Evaluator;
use evoengineer::methods::KernelRunRecord;
use evoengineer::report;
use evoengineer::runtime::Runtime;
use evoengineer::tasks::TaskRegistry;
use evoengineer::util::bench::Bench;

fn evaluator() -> Evaluator {
    let reg = Arc::new(
        TaskRegistry::load(PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")).unwrap(),
    );
    Evaluator::new(reg, Runtime::new().unwrap())
}

fn slice(
    ev: &Evaluator,
    methods: &[&str],
    models: &[&str],
    max_ops: usize,
    seeds: u64,
) -> Vec<KernelRunRecord> {
    let cfg = CampaignConfig {
        methods: methods.iter().map(|s| s.to_string()).collect(),
        models: models.iter().map(|s| s.to_string()).collect(),
        seeds: (0..seeds).collect(),
        max_ops,
        quiet: true,
        ..CampaignConfig::default()
    };
    campaign::run(&cfg, ev.clone()).unwrap()
}

fn main() {
    let ev = evaluator();
    let window = Duration::from_millis(1500);

    // Shared record sets (one campaign slice per paper artifact).
    println!("# building campaign slices for each table/figure...");
    let t0 = Instant::now();
    let recs_small = slice(&ev, &[], &["gpt"], 12, 2); // all methods
    let recs_evo = slice(
        &ev,
        &["evoengineer-free", "evoengineer-insight", "evoengineer-full"],
        &[],
        12,
        2,
    );
    let recs_ai = slice(&ev, &["ai cuda"], &["gpt"], 16, 2);
    println!("# slices built in {:.1}s\n", t0.elapsed().as_secs_f64());

    // --- Table 4: per-category speedup + validity -----------------------
    let mut b = Bench::new("table4").with_window(window);
    b.bench("campaign_slice+render", || {
        let recs = slice(&ev, &["evoengineer-full"], &["gpt"], 6, 1);
        report::table4(&recs)
    });
    println!("\n{}", report::table4(&recs_small));

    // --- Table 5: dataset composition -----------------------------------
    let mut b5 = Bench::new("table5").with_window(window);
    b5.bench("render", || report::table5(&ev.registry));
    println!("\n{}", report::table5(&ev.registry));

    // --- Figure 1: trade-off scatter -------------------------------------
    let mut b1 = Bench::new("fig1").with_window(window);
    b1.bench("aggregate+render", || report::fig1(&recs_small));
    println!("\n{}", report::fig1(&recs_small));

    // --- Figure 4 (+6/7): token usage ------------------------------------
    let mut b4 = Bench::new("fig4").with_window(window);
    b4.bench("aggregate+render", || report::fig4(&recs_small, "GPT"));
    println!("\n{}", report::fig4(&recs_small, "GPT"));

    // --- Figure 5: >2x vs PyTorch ----------------------------------------
    let mut bf5 = Bench::new("fig5").with_window(window);
    bf5.bench("aggregate+render", || report::fig5(&recs_evo));
    println!("\n{}", report::fig5(&recs_evo));

    // --- Table 7: speedup-range distribution ------------------------------
    let mut b7 = Bench::new("table7").with_window(window);
    b7.bench("aggregate+render", || report::table7(&recs_evo));
    println!("\n{}", report::table7(&recs_evo));

    // --- Figure 8: distribution summaries ---------------------------------
    let mut b8 = Bench::new("fig8").with_window(window);
    b8.bench("aggregate+render", || report::fig8(&recs_evo));
    println!("\n{}", report::fig8(&recs_evo));

    // --- Table 8 + Figure 9: AI CUDA Engineer replication ------------------
    let mut b89 = Bench::new("table8_fig9").with_window(window);
    b89.bench("aggregate+render", || {
        (report::table8(&recs_ai), report::fig9(&recs_ai))
    });
    println!("\n{}", report::table8(&recs_ai));
    println!("{}", report::fig9(&recs_ai));

    // --- Ablations (DESIGN.md §7) ------------------------------------------
    println!("\n# ablation: trial budget 15/45/90 (EvoEngineer-Full, GPT-4.1)");
    let mut ba = Bench::new("ablation_budget").with_window(window);
    for budget in [15usize, 45, 90] {
        let cfg = CampaignConfig {
            methods: vec!["evoengineer-full".into()],
            models: vec!["gpt".into()],
            seeds: vec![0],
            max_ops: 8,
            budget,
            quiet: true,
            ..CampaignConfig::default()
        };
        let recs = ba
            .bench(&format!("budget_{budget}"), || {
                campaign::run(&cfg, ev.clone()).unwrap()
            })
            .iters;
        let _ = recs;
        let recs = campaign::run(&cfg, ev.clone()).unwrap();
        let p = &evoengineer::metrics::tradeoff_points(&recs)[0];
        println!(
            "  budget {budget:>3}: median speedup {:.2}, functional {:.1}%",
            p.median_speedup, p.correct_rate
        );
    }

    println!("\n# ablation: population strategy at fixed info (insight/EoH/funsearch)");
    let recs = slice(
        &ev,
        &["evoengineer-insight", "evoengineer-solution", "funsearch"],
        &["claude"],
        12,
        2,
    );
    for p in evoengineer::metrics::tradeoff_points(&recs) {
        println!(
            "  {:<28} median speedup {:.2}, functional {:.1}%",
            p.method, p.median_speedup, p.correct_rate
        );
    }
    println!("\n# done — every paper table/figure regenerated above");
}
