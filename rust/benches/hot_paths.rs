//! Hot-path microbenchmarks (the criterion substitute; see
//! util::bench). These are the paths executed O(trials x runs) times in
//! a campaign — the targets of the EXPERIMENTS.md §Perf pass:
//!
//!   parse -> validate -> lower    (compile gate, per trial)
//!   price                         (cost model, per trial)
//!   render + generate             (prompt + SimLLM, per trial)
//!   session trial                 (everything, per trial)
//!   record JSON round-trip        (persistence, per run)
//!   contended functional testing  (stage-2 PJRT pairs, per shard count)

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use evoengineer::costmodel::{baseline_schedule, price, Gpu};
use evoengineer::dsl::{self, KernelSpec};
use evoengineer::evals::{functional_case_batch, Evaluator};
use evoengineer::llm::{self, SimProvider, MODELS};
use evoengineer::methods::{Archive, RepairPolicy, RunCtx, Session};
use evoengineer::population::SingleBest;
use evoengineer::runtime::{Runtime, TensorValue};
use evoengineer::tasks::{OpTask, TaskRegistry};
use evoengineer::traverse::prompt::render;
use evoengineer::traverse::{Guidance, GuidanceConfig};
use evoengineer::util::bench::Bench;
use evoengineer::util::Rng;

fn main() {
    let reg = Arc::new(
        TaskRegistry::load(PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")).unwrap(),
    );
    let evaluator = Evaluator::new(reg.clone(), Runtime::new().unwrap());
    let task = reg.get("matmul_64").unwrap().clone();
    let gpu = Gpu::rtx4090();

    let spec = KernelSpec {
        op: task.name.clone(),
        semantics: "opt".into(),
        schedule: baseline_schedule(&task),
    };
    let src = dsl::print(&spec);

    let mut b = Bench::new("dsl");
    b.bench("lex+parse", || dsl::parse(&src).unwrap());
    b.bench("print", || dsl::print(&spec));
    b.bench("validate", || dsl::validate(&spec).unwrap());
    b.bench("compile_front", || dsl::compile_front(&src).unwrap());
    b.report();

    let mut b = Bench::new("costmodel");
    b.bench("price", || price(&spec.schedule, &task, &gpu));
    b.bench("baseline_schedule", || baseline_schedule(&task));
    b.report();

    // Prompt render + SimLLM generation (information-rich prompt).
    let parent = {
        let mut rng = Rng::new(1);
        let outcome = evaluator.evaluate(&src, &task, &mut rng);
        match outcome {
            evoengineer::evals::EvalOutcome::Ok(s) => evoengineer::population::Candidate {
                src: src.clone(),
                spec: Some(spec.clone()),
                compiled: true,
                correct: true,
                speedup: s.speedup,
                pytorch_speedup: s.pytorch_speedup,
                true_speedup: s.true_speedup,
                true_pytorch_speedup: s.true_pytorch_speedup,
                insight: None,
                trial: 0,
            },
            other => panic!("{other:?}"),
        }
    };
    let ins = evoengineer::traverse::InsightRecord {
        text: "set vector_width to 8 (wider loads)".into(),
        delta: 0.4,
    };
    let guidance = Guidance {
        task: &task,
        baseline_us: 10.0,
        parent: Some(&parent),
        history: vec![&parent, &parent, &parent],
        insights: vec![&ins, &ins],
        profiling: Some("bound: Memory; occupancy: 0.66".into()),
        instruction: "Improve the current kernel.".into(),
    };
    let cfg = GuidanceConfig::full();
    let prompt = render(&cfg, &guidance);
    let mut b = Bench::new("llm");
    b.bench("render_prompt", || render(&cfg, &guidance));
    let mut i = 0u64;
    b.bench("generate", || {
        i += 1;
        let mut rng = Rng::new(i);
        llm::generate(&prompt, &MODELS[0], &mut rng)
    });
    b.report();

    // Full evaluation of an emitted candidate (memoized functional).
    let mut b = Bench::new("evals");
    let mut j = 0u64;
    b.bench("evaluate_valid", || {
        j += 1;
        let mut rng = Rng::new(j);
        evaluator.evaluate(&src, &task, &mut rng)
    });
    let bad = src.replacen(';', " ", 1);
    b.bench("evaluate_syntax_fail", || {
        let mut rng = Rng::new(3);
        evaluator.evaluate(&bad, &task, &mut rng)
    });
    b.report();

    // One complete trial through a Session (everything end to end).
    let archive = Archive::new();
    let provider = SimProvider::new();
    let ctx = RunCtx {
        evaluator: &evaluator,
        task: &task,
        model: &MODELS[0],
        seed: 0,
        archive: &archive,
        provider: &provider,
        budget: usize::MAX / 2,
        repair: RepairPolicy::Off,
    };
    let mut session = Session::new(&ctx, "bench");
    let mut pop = SingleBest::new();
    session.bootstrap(&mut pop);
    let mut b = Bench::new("session");
    b.bench("trial", || {
        session
            .trial(&cfg, &mut pop, "Improve the current kernel.", None, None)
            .unwrap()
    });
    b.report();

    // Record persistence — on a realistic record (45-trial trajectory),
    // not the mega-session above (whose trajectory is bench-inflated).
    let mut rec = session.finish("bench");
    rec.trajectory.truncate(45);
    let json = rec.to_json().to_string();
    let mut b = Bench::new("records");
    b.bench("to_json", || rec.to_json().to_string());
    b.bench("parse_json", || {
        evoengineer::methods::KernelRunRecord::from_json(
            &evoengineer::util::json::parse(&json).unwrap(),
        )
        .unwrap()
    });
    b.report();

    // Contended functional testing: 4 campaign-style workers hammering
    // uncached ref/candidate pair batches (the stage-2 path the old
    // single-owner runtime serialized). Throughput must scale with the
    // shard count; the acceptance bar is >= 2x for 4 shards vs 1 shard
    // under a 4-worker load.
    const WORKERS: usize = 4;
    const PAIRS_PER_WORKER: usize = 12;
    let t1 = contended_pairs_throughput(&reg, 1, WORKERS, PAIRS_PER_WORKER);
    let t4 = contended_pairs_throughput(&reg, 4, WORKERS, PAIRS_PER_WORKER);
    println!(
        "{:<40} {:>10.1} verdicts/s",
        "runtime/contended_pairs_1_shard", t1
    );
    println!(
        "{:<40} {:>10.1} verdicts/s",
        "runtime/contended_pairs_4_shards", t4
    );
    println!(
        "{:<40} {:>10.2}x  (target >= 2x)",
        "runtime/shard_scaling_4v1",
        t4 / t1
    );
    println!("# group `runtime`: 2 benchmarks + scaling ratio");
}

/// Measure ref/candidate pair-batch verdict throughput (pairs/sec)
/// under `workers` concurrent threads against a `shards`-shard pool.
/// Artifacts are pre-compiled and the case batches pre-generated (an
/// `Arc` clone per submission, exactly like the evaluator), so the
/// timed region measures contended PJRT execution only.
fn contended_pairs_throughput(
    reg: &Arc<TaskRegistry>,
    shards: usize,
    workers: usize,
    pairs_per_worker: usize,
) -> f64 {
    let rt = Runtime::with_shards(shards).unwrap();
    // A spread of small ops so the load distributes across shards; the
    // batches are the same ones Evaluator::functional_uncached submits.
    let ops: Vec<(OpTask, Arc<Vec<Vec<TensorValue>>>)> =
        ["tanh_64", "relu_64", "sigmoid_64", "silu_big", "layernorm_64",
            "softmax_256", "matmul_32", "kl_div_64"]
            .iter()
            .map(|&n| {
                let op = reg.get(n).expect(n).clone();
                let batch = functional_case_batch(&op);
                (op, batch)
            })
            .collect();
    // Warmup: compile every (ref, opt) executable on its shard.
    for (op, batch) in &ops {
        rt.execute_pairs(
            reg.artifact_path(op, "ref").unwrap(),
            reg.artifact_path(op, "opt").unwrap(),
            batch.clone(),
        )
        .unwrap();
    }
    let start = Instant::now();
    std::thread::scope(|scope| {
        for w in 0..workers {
            let rt = rt.clone();
            let ops = &ops;
            let reg = reg.clone();
            scope.spawn(move || {
                for i in 0..pairs_per_worker {
                    let (op, batch) = &ops[(w + i * workers) % ops.len()];
                    let (wants, gots) = rt
                        .execute_pairs(
                            reg.artifact_path(op, "ref").unwrap(),
                            reg.artifact_path(op, "opt").unwrap(),
                            batch.clone(),
                        )
                        .unwrap();
                    std::hint::black_box((wants, gots));
                }
            });
        }
    });
    (workers * pairs_per_worker) as f64 / start.elapsed().as_secs_f64()
}
