//! Hot-path microbenchmarks (the criterion substitute; see
//! util::bench). These are the paths executed O(trials x runs) times in
//! a campaign — the targets of the EXPERIMENTS.md §Perf pass:
//!
//!   parse -> validate -> lower    (compile gate, per trial)
//!   price                         (cost model, per trial)
//!   render + generate             (prompt + SimLLM, per trial)
//!   session trial                 (everything, per trial)
//!   record JSON round-trip        (persistence, per run)
//!   contended functional testing  (stage-2 PJRT pairs, per shard count)
//!   engine pipelining             (speculative generation prefetch vs
//!                                  a latency-injecting stub provider)

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use evoengineer::costmodel::{baseline_schedule, price, Gpu};
use evoengineer::dsl::{self, KernelSpec};
use evoengineer::evals::{functional_case_batch, Evaluator};
use evoengineer::llm::{
    self, GenerationRequest, GenerationResponse, Provider, SimProvider, TokenUsage, MODELS,
};
use evoengineer::guard;
use evoengineer::methods::engine::{self, EngineOpts};
use evoengineer::methods::{
    self, baseline_src, Archive, GenerateStep, RepairPolicy, RunCtx, Session,
};
use evoengineer::population::SingleBest;
use evoengineer::runtime::{Runtime, TensorValue};
use evoengineer::tasks::{OpTask, TaskRegistry};
use evoengineer::traverse::prompt::render;
use evoengineer::traverse::{Guidance, GuidanceConfig};
use evoengineer::util::bench::{self, Bench};
use evoengineer::util::Rng;

fn main() {
    let reg = Arc::new(
        TaskRegistry::load(PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")).unwrap(),
    );
    let evaluator = Evaluator::new(reg.clone(), Runtime::new().unwrap());
    let task = reg.get("matmul_64").unwrap().clone();
    let gpu = Gpu::rtx4090();

    let spec = KernelSpec {
        op: task.name.clone(),
        semantics: "opt".into(),
        schedule: baseline_schedule(&task),
    };
    let src = dsl::print(&spec);

    let mut b = Bench::new("dsl");
    b.bench("lex+parse", || dsl::parse(&src).unwrap());
    b.bench("print", || dsl::print(&spec));
    b.bench("validate", || dsl::validate(&spec).unwrap());
    b.bench("compile_front", || dsl::compile_front(&src).unwrap());
    b.report();

    let mut b = Bench::new("costmodel");
    b.bench("price", || price(&spec.schedule, &task, &gpu));
    b.bench("baseline_schedule", || baseline_schedule(&task));
    b.report();

    // Stage-0 guard batching (DESIGN.md §14): check_batch over every
    // baseline op plus a syntax-broken mutant of each — the candidate
    // batch a campaign screens per generation. check_source is pure
    // CPU with no shared state, so the scoped worker pool must hit
    // >= 2x at 4 workers over the sequential path.
    let guard_cases: Vec<(String, &OpTask)> = reg
        .ops
        .iter()
        .flat_map(|op| {
            let base = dsl::print(&KernelSpec {
                op: op.name.clone(),
                semantics: "opt".into(),
                schedule: baseline_schedule(op),
            });
            let broken = base.replacen(';', " ", 1);
            [(base, op), (broken, op)]
        })
        .collect();
    let guard_items: Vec<(&str, &OpTask)> =
        guard_cases.iter().map(|(s, op)| (s.as_str(), *op)).collect();
    let mut b = Bench::new("guard");
    let g1 = b.bench("check_batch_1_worker", || guard::check_batch(&guard_items, 1)).median;
    let g4 = b.bench("check_batch_4_workers", || guard::check_batch(&guard_items, 4)).median;
    b.report();
    bench::emit_ratio(
        "guard",
        "batch_4_workers_speedup",
        g1.as_secs_f64() / g4.as_secs_f64().max(1e-12),
        2.0,
    );

    // Prompt render + SimLLM generation (information-rich prompt).
    let parent = {
        let mut rng = Rng::new(1);
        let outcome = evaluator.evaluate(&src, &task, &mut rng);
        match outcome {
            evoengineer::evals::EvalOutcome::Ok(s) => evoengineer::population::Candidate {
                src: src.clone(),
                spec: Some(spec.clone()),
                compiled: true,
                correct: true,
                speedup: s.speedup,
                pytorch_speedup: s.pytorch_speedup,
                true_speedup: s.true_speedup,
                true_pytorch_speedup: s.true_pytorch_speedup,
                insight: None,
                trial: 0,
            },
            other => panic!("{other:?}"),
        }
    };
    let ins = evoengineer::traverse::InsightRecord {
        text: "set vector_width to 8 (wider loads)".into(),
        delta: 0.4,
    };
    let guidance = Guidance {
        task: &task,
        baseline_us: 10.0,
        parent: Some(&parent),
        history: vec![&parent, &parent, &parent],
        insights: vec![&ins, &ins],
        profiling: Some("bound: Memory; occupancy: 0.66".into()),
        instruction: "Improve the current kernel.".into(),
    };
    let cfg = GuidanceConfig::full();
    let prompt = render(&cfg, &guidance);
    let mut b = Bench::new("llm");
    b.bench("render_prompt", || render(&cfg, &guidance));
    let mut i = 0u64;
    b.bench("generate", || {
        i += 1;
        let mut rng = Rng::new(i);
        llm::generate(&prompt, &MODELS[0], &mut rng)
    });
    b.report();

    // Full evaluation of an emitted candidate (memoized functional).
    let mut b = Bench::new("evals");
    let mut j = 0u64;
    b.bench("evaluate_valid", || {
        j += 1;
        let mut rng = Rng::new(j);
        evaluator.evaluate(&src, &task, &mut rng)
    });
    let bad = src.replacen(';', " ", 1);
    b.bench("evaluate_syntax_fail", || {
        let mut rng = Rng::new(3);
        evaluator.evaluate(&bad, &task, &mut rng)
    });
    b.report();

    // One complete trial through a Session (everything end to end,
    // via the trial engine's single-trial entry point).
    let archive = Archive::new();
    let provider = SimProvider::new();
    let ctx = RunCtx {
        evaluator: &evaluator,
        task: &task,
        model: &MODELS[0],
        seed: 0,
        archive: &archive,
        provider: &provider,
        budget: usize::MAX / 2,
        repair: RepairPolicy::Off,
        feedback: Default::default(),
        bank: None,
        warm: None,
    };
    let mut session = Session::start(&ctx, "bench", Box::new(SingleBest::new()));
    session.seed(baseline_src(&ctx));
    let step = GenerateStep::new(cfg, "Improve the current kernel.");
    let mut b = Bench::new("session");
    b.bench("trial", || session.run_trial(&step).unwrap());
    b.report();

    // Record persistence — on a realistic record (45-trial trajectory),
    // not the mega-session above (whose trajectory is bench-inflated).
    let mut rec = session.finish();
    rec.trajectory.truncate(45);
    let json = rec.to_json().to_string();
    let mut b = Bench::new("records");
    b.bench("to_json", || rec.to_json().to_string());
    b.bench("parse_json", || {
        evoengineer::methods::KernelRunRecord::from_json(
            &evoengineer::util::json::parse(&json).unwrap(),
        )
        .unwrap()
    });
    b.report();

    // Contended functional testing: 4 campaign-style workers hammering
    // uncached ref/candidate pair batches (the stage-2 path the old
    // single-owner runtime serialized). Throughput must scale with the
    // shard count; the acceptance bar is >= 2x for 4 shards vs 1 shard
    // under a 4-worker load.
    const WORKERS: usize = 4;
    const PAIRS_PER_WORKER: usize = 12;
    let t1 = contended_pairs_throughput(&reg, 1, WORKERS, PAIRS_PER_WORKER);
    let t4 = contended_pairs_throughput(&reg, 4, WORKERS, PAIRS_PER_WORKER);
    println!(
        "{:<40} {:>10.1} verdicts/s",
        "runtime/contended_pairs_1_shard", t1
    );
    println!(
        "{:<40} {:>10.1} verdicts/s",
        "runtime/contended_pairs_4_shards", t4
    );
    println!(
        "{:<40} {:>10.2}x  (target >= 2x)",
        "runtime/shard_scaling_4v1",
        t4 / t1
    );
    println!("# group `runtime`: 2 benchmarks + scaling ratio");

    // Engine pipelining: trials/sec against a provider with 200 ms of
    // injected generation latency (the HTTP regime). Speculative
    // prefetch overlaps provider calls for predicted future trials
    // with the current trial's compile+bench; 4 workers additionally
    // parallelize the speculation depth. Acceptance bar: >= 1.5x for
    // 4 prefetch workers vs 1.
    const PIPE_BUDGET: usize = 8;
    let p1 = pipelined_trials_per_sec(&evaluator, &task, 1, PIPE_BUDGET);
    let p4 = pipelined_trials_per_sec(&evaluator, &task, 4, PIPE_BUDGET);
    println!(
        "{:<40} {:>10.1} trials/s",
        "engine_pipelining/1_prefetch_worker", p1
    );
    println!(
        "{:<40} {:>10.1} trials/s",
        "engine_pipelining/4_prefetch_workers", p4
    );
    println!(
        "{:<40} {:>10.2}x  (target >= 1.5x)",
        "engine_pipelining/scaling_4v1",
        p4 / p1
    );
    println!("# group `engine_pipelining`: 2 benchmarks + scaling ratio");
}

/// Provider stub injecting a fixed generation latency (the live-HTTP
/// regime the prefetch engine exists for). The emission is constant
/// and invalid, so the population never changes and speculation hits
/// every trial — the bench measures pure pipelining headroom.
struct LatencyProvider {
    delay: Duration,
}

impl Provider for LatencyProvider {
    fn label(&self) -> &str {
        "latency-stub"
    }

    fn call(&self, _req: &GenerationRequest) -> evoengineer::Result<GenerationResponse> {
        std::thread::sleep(self.delay);
        Ok(GenerationResponse {
            text: "kernel bench { semantics opt".into(), // syntax-fails fast
            insight: "stub".into(),
            usage: TokenUsage { prompt_tokens: 10, completion_tokens: 10 },
        })
    }
}

/// Drive one EvoEngineer-Free cell with `prefetch` speculation workers
/// against the 200 ms latency stub and report trials/sec.
fn pipelined_trials_per_sec(
    evaluator: &Evaluator,
    task: &OpTask,
    prefetch: usize,
    budget: usize,
) -> f64 {
    let archive = Archive::new();
    let provider = LatencyProvider { delay: Duration::from_millis(200) };
    let ctx = RunCtx {
        evaluator,
        task,
        model: &MODELS[0],
        seed: 0,
        archive: &archive,
        provider: &provider,
        budget,
        repair: RepairPolicy::Off,
        feedback: Default::default(),
        bank: None,
        warm: None,
    };
    let method = methods::by_name("evoengineer-free").unwrap();
    let opts = EngineOpts { prefetch, ..EngineOpts::default() };
    let start = Instant::now();
    let rec = engine::drive(method.as_ref(), &ctx, &opts).unwrap();
    rec.trials as f64 / start.elapsed().as_secs_f64()
}

/// Measure ref/candidate pair-batch verdict throughput (pairs/sec)
/// under `workers` concurrent threads against a `shards`-shard pool.
/// Artifacts are pre-compiled and the case batches pre-generated (an
/// `Arc` clone per submission, exactly like the evaluator), so the
/// timed region measures contended PJRT execution only.
fn contended_pairs_throughput(
    reg: &Arc<TaskRegistry>,
    shards: usize,
    workers: usize,
    pairs_per_worker: usize,
) -> f64 {
    let rt = Runtime::with_shards(shards).unwrap();
    // A spread of small ops so the load distributes across shards; the
    // batches are the same ones Evaluator::functional_uncached submits.
    let ops: Vec<(OpTask, Arc<Vec<Vec<TensorValue>>>)> =
        ["tanh_64", "relu_64", "sigmoid_64", "silu_big", "layernorm_64",
            "softmax_256", "matmul_32", "kl_div_64"]
            .iter()
            .map(|&n| {
                let op = reg.get(n).expect(n).clone();
                let batch = functional_case_batch(&op);
                (op, batch)
            })
            .collect();
    // Warmup: compile every (ref, opt) executable on its shard.
    for (op, batch) in &ops {
        rt.execute_pairs(
            reg.artifact_path(op, "ref").unwrap(),
            reg.artifact_path(op, "opt").unwrap(),
            batch.clone(),
        )
        .unwrap();
    }
    let start = Instant::now();
    std::thread::scope(|scope| {
        for w in 0..workers {
            let rt = rt.clone();
            let ops = &ops;
            let reg = reg.clone();
            scope.spawn(move || {
                for i in 0..pairs_per_worker {
                    let (op, batch) = &ops[(w + i * workers) % ops.len()];
                    let (wants, gots) = rt
                        .execute_pairs(
                            reg.artifact_path(op, "ref").unwrap(),
                            reg.artifact_path(op, "opt").unwrap(),
                            batch.clone(),
                        )
                        .unwrap();
                    std::hint::black_box((wants, gots));
                }
            });
        }
    });
    (workers * pairs_per_worker) as f64 / start.elapsed().as_secs_f64()
}
