//! Hot-path microbenchmarks (the criterion substitute; see
//! util::bench). These are the paths executed O(trials x runs) times in
//! a campaign — the targets of the EXPERIMENTS.md §Perf pass:
//!
//!   parse -> validate -> lower    (compile gate, per trial)
//!   price                         (cost model, per trial)
//!   render + generate             (prompt + SimLLM, per trial)
//!   session trial                 (everything, per trial)
//!   record JSON round-trip        (persistence, per run)

use std::path::PathBuf;
use std::sync::Arc;

use evoengineer::costmodel::{baseline_schedule, price, Gpu};
use evoengineer::dsl::{self, KernelSpec};
use evoengineer::evals::Evaluator;
use evoengineer::llm::{self, MODELS};
use evoengineer::methods::{Archive, RunCtx, Session};
use evoengineer::population::SingleBest;
use evoengineer::runtime::Runtime;
use evoengineer::tasks::TaskRegistry;
use evoengineer::traverse::prompt::render;
use evoengineer::traverse::{Guidance, GuidanceConfig};
use evoengineer::util::bench::Bench;
use evoengineer::util::Rng;

fn main() {
    let reg = Arc::new(
        TaskRegistry::load(PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")).unwrap(),
    );
    let evaluator = Evaluator::new(reg.clone(), Runtime::new().unwrap());
    let task = reg.get("matmul_64").unwrap().clone();
    let gpu = Gpu::rtx4090();

    let spec = KernelSpec {
        op: task.name.clone(),
        semantics: "opt".into(),
        schedule: baseline_schedule(&task),
    };
    let src = dsl::print(&spec);

    let mut b = Bench::new("dsl");
    b.bench("lex+parse", || dsl::parse(&src).unwrap());
    b.bench("print", || dsl::print(&spec));
    b.bench("validate", || dsl::validate(&spec).unwrap());
    b.bench("compile_front", || dsl::compile_front(&src).unwrap());
    b.report();

    let mut b = Bench::new("costmodel");
    b.bench("price", || price(&spec.schedule, &task, &gpu));
    b.bench("baseline_schedule", || baseline_schedule(&task));
    b.report();

    // Prompt render + SimLLM generation (information-rich prompt).
    let parent = {
        let mut rng = Rng::new(1);
        let outcome = evaluator.evaluate(&src, &task, &mut rng);
        match outcome {
            evoengineer::evals::EvalOutcome::Ok(s) => evoengineer::population::Candidate {
                src: src.clone(),
                spec: Some(spec.clone()),
                compiled: true,
                correct: true,
                speedup: s.speedup,
                pytorch_speedup: s.pytorch_speedup,
                true_speedup: s.true_speedup,
                true_pytorch_speedup: s.true_pytorch_speedup,
                insight: None,
                trial: 0,
            },
            other => panic!("{other:?}"),
        }
    };
    let ins = evoengineer::traverse::InsightRecord {
        text: "set vector_width to 8 (wider loads)".into(),
        delta: 0.4,
    };
    let guidance = Guidance {
        task: &task,
        baseline_us: 10.0,
        parent: Some(&parent),
        history: vec![&parent, &parent, &parent],
        insights: vec![&ins, &ins],
        profiling: Some("bound: Memory; occupancy: 0.66".into()),
        instruction: "Improve the current kernel.".into(),
    };
    let cfg = GuidanceConfig::full();
    let prompt = render(&cfg, &guidance);
    let mut b = Bench::new("llm");
    b.bench("render_prompt", || render(&cfg, &guidance));
    let mut i = 0u64;
    b.bench("generate", || {
        i += 1;
        let mut rng = Rng::new(i);
        llm::generate(&prompt, &MODELS[0], &mut rng)
    });
    b.report();

    // Full evaluation of an emitted candidate (memoized functional).
    let mut b = Bench::new("evals");
    let mut j = 0u64;
    b.bench("evaluate_valid", || {
        j += 1;
        let mut rng = Rng::new(j);
        evaluator.evaluate(&src, &task, &mut rng)
    });
    let bad = src.replacen(';', " ", 1);
    b.bench("evaluate_syntax_fail", || {
        let mut rng = Rng::new(3);
        evaluator.evaluate(&bad, &task, &mut rng)
    });
    b.report();

    // One complete trial through a Session (everything end to end).
    let archive = Archive::new();
    let ctx = RunCtx {
        evaluator: &evaluator,
        task: &task,
        model: &MODELS[0],
        seed: 0,
        archive: &archive,
        budget: usize::MAX / 2,
    };
    let mut session = Session::new(&ctx, "bench");
    let mut pop = SingleBest::new();
    session.bootstrap(&mut pop);
    let mut b = Bench::new("session");
    b.bench("trial", || {
        session
            .trial(&cfg, &mut pop, "Improve the current kernel.", None, None)
            .unwrap()
    });
    b.report();

    // Record persistence — on a realistic record (45-trial trajectory),
    // not the mega-session above (whose trajectory is bench-inflated).
    let mut rec = session.finish("bench");
    rec.trajectory.truncate(45);
    let json = rec.to_json().to_string();
    let mut b = Bench::new("records");
    b.bench("to_json", || rec.to_json().to_string());
    b.bench("parse_json", || {
        evoengineer::methods::KernelRunRecord::from_json(
            &evoengineer::util::json::parse(&json).unwrap(),
        )
        .unwrap()
    });
    b.report();
}
