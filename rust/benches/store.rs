//! Evaluation-cache benchmarks (DESIGN.md §8, §14): the warm-cache hit
//! path vs a cold pipeline evaluation, the keying overhead itself, and
//! the journal hot paths the §14 speed pass targets:
//!
//!   journal   — opening a ≥10k-record store via the sidecar offset
//!               index vs a full JSONL rescan (target ≥5×)
//!   append    — group-commit batched appends vs flush-per-record
//!   intern    — warm interned keying vs re-canonicalizing every call
//!
//! The acceptance target for the persistent store is a ≥10× win for a
//! warm hit over a cold evaluation. "Cold" here means the in-process
//! memos are dropped before every iteration, so each cold evaluation
//! pays the real pipeline: compile front-end, artifact resolution,
//! five PJRT functional cases, and cost-model pricing. "Warm" drops
//! the same memos but serves the verdict from the persistent store —
//! the replay that a resumed or deduplicated campaign runs instead of
//! the pipeline.

use std::path::PathBuf;
use std::sync::Arc;

use evoengineer::costmodel::baseline_schedule;
use evoengineer::dsl::{self, KernelSpec};
use evoengineer::evals::Evaluator;
use evoengineer::runtime::Runtime;
use evoengineer::store::{
    key_for_source, EvalKey, EvalStore, IndexMode, KeyInterner, Keyed, StoredEval, StoredOutcome,
};
use evoengineer::tasks::TaskRegistry;
use evoengineer::util::bench::{self, Bench};
use evoengineer::util::Rng;

/// Cheap synthetic journal entry (compile failures carry the least
/// payload; the open benchmarks measure record *count* scaling).
fn synth_entry(i: u64) -> StoredEval {
    StoredEval {
        op: "matmul_64".into(),
        model: "bench".into(),
        outcome: StoredOutcome::CompileFail { error: format!("synthetic failure {i}") },
    }
}

fn main() {
    let reg = Arc::new(
        TaskRegistry::load(PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")).unwrap(),
    );
    let task = reg.get("matmul_64").unwrap().clone();
    let src = dsl::print(&KernelSpec {
        op: task.name.clone(),
        semantics: "opt".into(),
        schedule: baseline_schedule(&task),
    });

    let cache = std::env::temp_dir().join(format!("evo_bench_cache_{}.jsonl", std::process::id()));
    std::fs::remove_file(&cache).ok();

    let cold_ev = Evaluator::new(reg.clone(), Runtime::new().unwrap());
    let warm_ev = Evaluator::new(reg.clone(), Runtime::new().unwrap())
        .with_store(EvalStore::open(&cache).unwrap());
    {
        // Populate the store with the candidate (one real evaluation).
        let mut rng = Rng::new(0);
        warm_ev.evaluate(&src, &task, &mut rng);
        assert_eq!(warm_ev.store().unwrap().len(), 1);
    }

    let mut b = Bench::new("store");
    b.bench("key_for_source", || key_for_source(&task.name, &src).unwrap());

    let mut i = 0u64;
    let cold = b
        .bench("evaluate_cold", || {
            i += 1;
            cold_ev.clear_memos();
            let mut rng = Rng::new(i);
            cold_ev.evaluate(&src, &task, &mut rng)
        })
        .median;

    let mut j = 0u64;
    let warm = b
        .bench("evaluate_warm_hit", || {
            j += 1;
            warm_ev.clear_memos();
            let mut rng = Rng::new(j);
            warm_ev.evaluate(&src, &task, &mut rng)
        })
        .median;
    b.report();

    let speedup = cold.as_secs_f64() / warm.as_secs_f64().max(1e-12);
    println!(
        "\nwarm-cache hit is {speedup:.1}x faster than cold evaluation (target >= 10x): {}",
        if speedup >= 10.0 { "PASS" } else { "FAIL" }
    );
    std::fs::remove_file(&cache).ok();

    // ---- journal: indexed open vs full rescan on a 12k-record store.
    // The sidecar index (DESIGN.md §14) turns open from "parse every
    // JSON body" into "read offset table + validate covered tail";
    // the acceptance bar is >= 5x on >= 10k records.
    const JOURNAL_RECORDS: u64 = 12_000;
    let journal =
        std::env::temp_dir().join(format!("evo_bench_journal_{}.jsonl", std::process::id()));
    std::fs::remove_file(&journal).ok();
    evoengineer::store::index::delete_sidecar(&journal);
    {
        let store = EvalStore::open_with(&journal, IndexMode::Off).unwrap();
        for i in 0..JOURNAL_RECORDS {
            let key = EvalKey::from_canonical("matmul_64", &format!("synthetic {i}"));
            store.record(&key, synth_entry(i)).unwrap();
        }
        store.flush().unwrap();
    }
    {
        // Prime the sidecar: the first Auto open scans and persists it.
        let store = EvalStore::open_with(&journal, IndexMode::Auto).unwrap();
        assert_eq!(store.len(), JOURNAL_RECORDS as usize);
    }
    let mut b = Bench::new("journal");
    let rescan = b
        .bench("open_12k_full_rescan", || {
            let s = EvalStore::open_with(&journal, IndexMode::Off).unwrap();
            assert!(!s.opened_indexed());
            s.len()
        })
        .median;
    let indexed = b
        .bench("open_12k_indexed", || {
            let s = EvalStore::open_with(&journal, IndexMode::Auto).unwrap();
            assert!(s.opened_indexed());
            s.len()
        })
        .median;
    b.report();
    bench::emit_ratio(
        "journal",
        "indexed_open_speedup",
        rescan.as_secs_f64() / indexed.as_secs_f64().max(1e-12),
        5.0,
    );
    evoengineer::store::index::delete_sidecar(&journal);
    std::fs::remove_file(&journal).ok();

    // ---- append: flush-per-record vs group-commit batching. The
    // grouped path stages records in the GroupWriter buffer and pays
    // one write+flush per 64-record batch (the engine flushes at trial
    // boundaries); the per-record path models the pre-§14 behaviour.
    let each_path =
        std::env::temp_dir().join(format!("evo_bench_append_each_{}.jsonl", std::process::id()));
    let grouped_path =
        std::env::temp_dir().join(format!("evo_bench_append_grp_{}.jsonl", std::process::id()));
    std::fs::remove_file(&each_path).ok();
    std::fs::remove_file(&grouped_path).ok();
    let each_store = EvalStore::open_with(&each_path, IndexMode::Off).unwrap();
    let grouped_store = EvalStore::open_with(&grouped_path, IndexMode::Off).unwrap();
    let mut b = Bench::new("append");
    let mut n = 0u64;
    let per_record = b
        .bench("record_flush_each", || {
            n += 1;
            let key = EvalKey::from_canonical("matmul_64", &format!("each {n}"));
            each_store.record(&key, synth_entry(n)).unwrap();
            each_store.flush().unwrap();
        })
        .median;
    let mut m = 0u64;
    let grouped = b
        .bench("record_group_commit", || {
            m += 1;
            let key = EvalKey::from_canonical("matmul_64", &format!("grp {m}"));
            grouped_store.record(&key, synth_entry(m)).unwrap();
            if m % 64 == 0 {
                grouped_store.flush().unwrap();
            }
        })
        .median;
    b.report();
    println!(
        "{:<40} {:>10.2}x",
        "append/group_commit_speedup",
        per_record.as_secs_f64() / grouped.as_secs_f64().max(1e-12)
    );
    drop(each_store);
    drop(grouped_store);
    std::fs::remove_file(&each_path).ok();
    std::fs::remove_file(&grouped_path).ok();

    // ---- intern: the canonical-print -> SHA-256 keying path, cold
    // (fresh interner, pays parse+print+hash every call) vs warm (the
    // evaluator's shared interner serving the memoized key).
    let mut b = Bench::new("intern");
    b.bench("key_cold", || {
        let interner = KeyInterner::new();
        match interner.key_for(&task.name, &src) {
            Keyed::Key(k) => k,
            Keyed::Unparseable(e) => panic!("{e}"),
        }
    });
    let warm_interner = KeyInterner::new();
    b.bench("key_warm", || match warm_interner.key_for(&task.name, &src) {
        Keyed::Key(k) => k,
        Keyed::Unparseable(e) => panic!("{e}"),
    });
    b.report();
}
