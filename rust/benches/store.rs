//! Evaluation-cache benchmarks (DESIGN.md §8): the warm-cache hit path
//! vs a cold pipeline evaluation, plus the keying overhead itself.
//!
//! The acceptance target for the persistent store is a ≥10× win for a
//! warm hit over a cold evaluation. "Cold" here means the in-process
//! memos are dropped before every iteration, so each cold evaluation
//! pays the real pipeline: compile front-end, artifact resolution,
//! five PJRT functional cases, and cost-model pricing. "Warm" drops
//! the same memos but serves the verdict from the persistent store —
//! the replay that a resumed or deduplicated campaign runs instead of
//! the pipeline.

use std::path::PathBuf;
use std::sync::Arc;

use evoengineer::costmodel::baseline_schedule;
use evoengineer::dsl::{self, KernelSpec};
use evoengineer::evals::Evaluator;
use evoengineer::runtime::Runtime;
use evoengineer::store::{key_for_source, EvalStore};
use evoengineer::tasks::TaskRegistry;
use evoengineer::util::bench::Bench;
use evoengineer::util::Rng;

fn main() {
    let reg = Arc::new(
        TaskRegistry::load(PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")).unwrap(),
    );
    let task = reg.get("matmul_64").unwrap().clone();
    let src = dsl::print(&KernelSpec {
        op: task.name.clone(),
        semantics: "opt".into(),
        schedule: baseline_schedule(&task),
    });

    let cache = std::env::temp_dir().join(format!("evo_bench_cache_{}.jsonl", std::process::id()));
    std::fs::remove_file(&cache).ok();

    let cold_ev = Evaluator::new(reg.clone(), Runtime::new().unwrap());
    let warm_ev = Evaluator::new(reg.clone(), Runtime::new().unwrap())
        .with_store(EvalStore::open(&cache).unwrap());
    {
        // Populate the store with the candidate (one real evaluation).
        let mut rng = Rng::new(0);
        warm_ev.evaluate(&src, &task, &mut rng);
        assert_eq!(warm_ev.store().unwrap().len(), 1);
    }

    let mut b = Bench::new("store");
    b.bench("key_for_source", || key_for_source(&task.name, &src).unwrap());

    let mut i = 0u64;
    let cold = b
        .bench("evaluate_cold", || {
            i += 1;
            cold_ev.clear_memos();
            let mut rng = Rng::new(i);
            cold_ev.evaluate(&src, &task, &mut rng)
        })
        .median;

    let mut j = 0u64;
    let warm = b
        .bench("evaluate_warm_hit", || {
            j += 1;
            warm_ev.clear_memos();
            let mut rng = Rng::new(j);
            warm_ev.evaluate(&src, &task, &mut rng)
        })
        .median;
    b.report();

    let speedup = cold.as_secs_f64() / warm.as_secs_f64().max(1e-12);
    println!(
        "\nwarm-cache hit is {speedup:.1}x faster than cold evaluation (target >= 10x): {}",
        if speedup >= 10.0 { "PASS" } else { "FAIL" }
    );
    std::fs::remove_file(&cache).ok();
}
