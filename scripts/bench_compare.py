#!/usr/bin/env python3
"""Gate a fresh bench artifact against the committed trajectory.

Compares a just-produced BENCH_<date>.json (see scripts/bench.sh and
DESIGN.md §14) against the newest committed BENCH_*.json baseline:

  * every benchmark present in both must not regress its median by
    more than --tolerance (default 20%);
  * every ratio in the current artifact must meet its own recorded
    target (e.g. journal/indexed_open_speedup >= 5x);
  * benches that appear or disappear are reported but never fail the
    gate (renames and new coverage are part of a normal speed pass).

Baselines whose provenance is not "measured" (the bootstrap sentinel
committed before a Rust toolchain could run the suite) are skipped
with a warning: comparing against fabricated or null numbers would be
meaningless. If no measured baseline exists at all, only the ratio
targets are enforced.
"""

import argparse
import glob
import json
import sys


def load(path):
    with open(path, encoding="utf-8") as f:
        return json.load(f)


def find_baseline(current_path):
    """Newest committed measured BENCH_*.json other than the current."""
    candidates = sorted(glob.glob("BENCH_*.json"), reverse=True)
    for path in candidates:
        if path == current_path:
            continue
        try:
            art = load(path)
        except (OSError, json.JSONDecodeError) as e:
            print(f"warning: baseline {path} unreadable, skipped ({e})",
                  file=sys.stderr)
            continue
        if art.get("provenance") != "measured":
            print(f"warning: baseline {path} has provenance "
                  f"{art.get('provenance')!r}, skipped (not measured)",
                  file=sys.stderr)
            continue
        return path, art
    return None, None


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--current", required=True, help="fresh BENCH_<date>.json")
    ap.add_argument("--baseline", help="explicit baseline (default: newest "
                    "committed measured BENCH_*.json)")
    ap.add_argument("--tolerance", type=float, default=0.20,
                    help="allowed fractional median regression (default 0.20)")
    args = ap.parse_args()

    current = load(args.current)
    failures = []

    # Ratio targets are self-contained: enforce them unconditionally.
    for r in current.get("ratios", []):
        label = f"{r['group']}/{r['name']}"
        if r["value"] is None:
            failures.append(f"ratio {label}: no measured value")
        elif r["value"] < r["target"]:
            failures.append(f"ratio {label}: {r['value']:.2f}x is below the "
                            f"{r['target']}x target")
        else:
            print(f"ok: ratio {label}: {r['value']:.2f}x >= {r['target']}x")

    if args.baseline:
        base_path, baseline = args.baseline, load(args.baseline)
        if baseline.get("provenance") != "measured":
            sys.exit(f"error: explicit baseline {base_path} is not measured")
    else:
        base_path, baseline = find_baseline(args.current)

    if baseline is None:
        print("warning: no measured committed baseline — median regression "
              "check skipped (first measured artifact bootstraps the "
              "trajectory)", file=sys.stderr)
    else:
        print(f"baseline: {base_path} ({baseline.get('date')}, "
              f"git {baseline.get('git')})")
        base_by_key = {(b["group"], b["name"]): b
                       for b in baseline.get("benches", [])}
        cur_keys = set()
        for b in current.get("benches", []):
            key = (b["group"], b["name"])
            cur_keys.add(key)
            old = base_by_key.get(key)
            label = f"{key[0]}/{key[1]}"
            if old is None:
                print(f"note: new bench {label} (no baseline)")
                continue
            if not old.get("median_ns") or not b.get("median_ns"):
                print(f"note: {label}: missing median, not compared")
                continue
            ratio = b["median_ns"] / old["median_ns"]
            if ratio > 1.0 + args.tolerance:
                failures.append(
                    f"bench {label}: median regressed {ratio:.2f}x "
                    f"({old['median_ns']} -> {b['median_ns']} ns, "
                    f"tolerance {args.tolerance:.0%})")
            else:
                print(f"ok: bench {label}: {ratio:.2f}x of baseline median")
        for key in sorted(set(base_by_key) - cur_keys):
            print(f"note: bench {key[0]}/{key[1]} vanished from the suite")

    if failures:
        print(f"\nFAIL ({len(failures)}):", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        sys.exit(1)
    print("\nbench gate PASS")


if __name__ == "__main__":
    main()
