#!/usr/bin/env bash
# Download the xla_extension native library (the PJRT implementation
# behind the rust `xla` crate) and verify it against the pinned SHA-256
# in scripts/xla_extension.sha256 before unpacking — a release tarball
# swapped underneath us must fail loudly, not link silently.
#
# Trust-on-first-use: while the pin file still holds the REPLACE_ME
# sentinel, the script prints the computed digest (and writes it to the
# GitHub step summary when available) and proceeds with a loud warning,
# so CI stays green until a maintainer commits the recorded value; once
# a real pin is present, any mismatch is a hard failure.
#
# Usage: scripts/fetch_xla_extension.sh   (in CI; exports env via
#        $GITHUB_ENV when set, prints exports otherwise)
set -euo pipefail
cd "$(dirname "$0")/.."

URL="${XLA_EXTENSION_URL:-https://github.com/elixir-nx/xla/releases/download/v0.4.4/xla_extension-x86_64-linux-gnu-cpu.tar.gz}"
PIN_FILE="scripts/xla_extension.sha256"
TARBALL="xla_extension.tar.gz"

curl -fsSL -o "$TARBALL" "$URL"
DIGEST="$(sha256sum "$TARBALL" | awk '{print $1}')"
PINNED="$(awk '{print $1}' "$PIN_FILE")"

if [ "$PINNED" = "REPLACE_ME" ]; then
  echo "WARNING: xla_extension pin is the REPLACE_ME sentinel — download NOT verified."
  echo "Computed digest of $URL:"
  echo "  $DIGEST"
  echo "Activate the pin:  echo '$DIGEST  $TARBALL' > $PIN_FILE"
  if [ -n "${GITHUB_STEP_SUMMARY:-}" ]; then
    {
      echo "### :warning: xla_extension checksum unpinned (trust-on-first-use)"
      echo '```'
      echo "$DIGEST  $TARBALL"
      echo '```'
      echo "Commit this into \`$PIN_FILE\` to activate enforcement."
    } >> "$GITHUB_STEP_SUMMARY"
  fi
elif [ "$DIGEST" != "$PINNED" ]; then
  echo "xla_extension checksum mismatch!" >&2
  echo "  pinned:   $PINNED ($PIN_FILE)" >&2
  echo "  computed: $DIGEST" >&2
  exit 1
else
  echo "xla_extension checksum OK ($DIGEST)"
fi

tar xzf "$TARBALL"
if [ -n "${GITHUB_ENV:-}" ]; then
  echo "XLA_EXTENSION_DIR=$PWD/xla_extension" >> "$GITHUB_ENV"
  echo "LD_LIBRARY_PATH=$PWD/xla_extension/lib:${LD_LIBRARY_PATH:-}" >> "$GITHUB_ENV"
else
  echo "export XLA_EXTENSION_DIR=$PWD/xla_extension"
  echo "export LD_LIBRARY_PATH=$PWD/xla_extension/lib:${LD_LIBRARY_PATH:-}"
fi
