#!/usr/bin/env bash
# Download the xla_extension native library (the PJRT implementation
# behind the rust `xla` crate) and verify it against the pinned SHA-256
# in scripts/xla_extension.sha256 before unpacking.
#
# Enforcement is unconditional — there is no trust-on-first-use path:
#
#   * digest mismatch           -> hard failure (a release tarball swapped
#                                  underneath us must fail loudly, not
#                                  link silently);
#   * pin file missing/UNPINNED -> hard failure with the recording
#                                  one-liner (an unpinned download is a
#                                  silent supply-chain hole, not a warning).
#
# To (re)record the pin from a machine you trust:
#
#   scripts/fetch_xla_extension.sh --record-pin
#
# which downloads the tarball, writes its digest to the pin file, and
# unpacks it. Verify the recorded value against an independent source
# (e.g. a second network path) before committing it.
#
# Usage: scripts/fetch_xla_extension.sh [--record-pin]
#        (in CI; exports env via $GITHUB_ENV when set, prints exports
#        otherwise)
set -euo pipefail
cd "$(dirname "$0")/.."

URL="${XLA_EXTENSION_URL:-https://github.com/elixir-nx/xla/releases/download/v0.4.4/xla_extension-x86_64-linux-gnu-cpu.tar.gz}"
PIN_FILE="scripts/xla_extension.sha256"
TARBALL="xla_extension.tar.gz"
RECORD_PIN=0
if [ "${1:-}" = "--record-pin" ]; then
  RECORD_PIN=1
fi

curl -fsSL -o "$TARBALL" "$URL"
DIGEST="$(sha256sum "$TARBALL" | awk '{print $1}')"

if [ "$RECORD_PIN" = 1 ]; then
  echo "$DIGEST  $TARBALL" > "$PIN_FILE"
  echo "recorded pin for $URL:"
  echo "  $DIGEST"
  echo "Verify this digest against an independent source, then commit $PIN_FILE."
else
  PINNED="$(awk 'NR==1 {print $1}' "$PIN_FILE" 2>/dev/null || true)"
  if [ -z "$PINNED" ] || [ "$PINNED" = "UNPINNED" ] || [ "$PINNED" = "REPLACE_ME" ]; then
    echo "xla_extension checksum pin is not recorded — refusing the unverified download." >&2
    echo "  computed digest of $URL:" >&2
    echo "    $DIGEST" >&2
    echo "  record it from a trusted machine with:" >&2
    echo "    scripts/fetch_xla_extension.sh --record-pin" >&2
    if [ -n "${GITHUB_STEP_SUMMARY:-}" ]; then
      {
        echo "### :x: xla_extension pin not recorded — job failed by design"
        echo "One-time bootstrap: verify this digest against an independent"
        echo "download, then commit it as \`$PIN_FILE\`:"
        echo '```'
        echo "$DIGEST  $TARBALL"
        echo '```'
      } >> "$GITHUB_STEP_SUMMARY"
    fi
    exit 1
  elif [ "$DIGEST" != "$PINNED" ]; then
    echo "xla_extension checksum mismatch!" >&2
    echo "  pinned:   $PINNED ($PIN_FILE)" >&2
    echo "  computed: $DIGEST" >&2
    echo "Either the upstream release changed or the download was tampered with." >&2
    echo "Investigate before re-recording the pin (--record-pin)." >&2
    exit 1
  else
    echo "xla_extension checksum OK ($DIGEST)"
  fi
fi

tar xzf "$TARBALL"
if [ -n "${GITHUB_ENV:-}" ]; then
  echo "XLA_EXTENSION_DIR=$PWD/xla_extension" >> "$GITHUB_ENV"
  echo "LD_LIBRARY_PATH=$PWD/xla_extension/lib:${LD_LIBRARY_PATH:-}" >> "$GITHUB_ENV"
else
  echo "export XLA_EXTENSION_DIR=$PWD/xla_extension"
  echo "export LD_LIBRARY_PATH=$PWD/xla_extension/lib:${LD_LIBRARY_PATH:-}"
fi
