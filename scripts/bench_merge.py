#!/usr/bin/env python3
"""Merge an EVO_BENCH_JSON raw stream into a BENCH_<date>.json artifact.

The bench harness (rust/src/util/bench.rs) appends one JSONL line per
finished benchmark ({"type":"bench",...}) and per derived ratio
({"type":"ratio",...}). This script folds that stream into the single
committed artifact described in DESIGN.md §14:

    {
      "schema": 1,
      "date": "YYYY-MM-DD",
      "git": "<short sha or null>",
      "provenance": "measured",
      "benches": [{"group","name","median_ns","p10_ns","p90_ns","iters"}],
      "ratios":  [{"group","name","value","target"}]
    }

Duplicate (group, name) pairs keep the LAST occurrence — a re-run in
the same process supersedes earlier samples.
"""

import argparse
import json
import subprocess
import sys

SCHEMA = 1


def git_short_sha():
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10, check=True,
        )
        return out.stdout.strip() or None
    except Exception:
        return None


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--raw", required=True, help="EVO_BENCH_JSON stream (JSONL)")
    ap.add_argument("--date", required=True, help="artifact date (YYYY-MM-DD)")
    ap.add_argument("--out", required=True, help="merged artifact path")
    args = ap.parse_args()

    benches, ratios = {}, {}
    with open(args.raw, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                print(f"warning: {args.raw}:{lineno}: unparseable line skipped ({e})",
                      file=sys.stderr)
                continue
            key = (rec.get("group"), rec.get("name"))
            if None in key:
                print(f"warning: {args.raw}:{lineno}: missing group/name, skipped",
                      file=sys.stderr)
                continue
            if rec.get("type") == "bench":
                benches[key] = {
                    "group": rec["group"], "name": rec["name"],
                    "median_ns": rec["median_ns"],
                    "p10_ns": rec["p10_ns"], "p90_ns": rec["p90_ns"],
                    "iters": rec["iters"],
                }
            elif rec.get("type") == "ratio":
                ratios[key] = {
                    "group": rec["group"], "name": rec["name"],
                    "value": rec["value"], "target": rec["target"],
                }

    if not benches:
        sys.exit(f"error: no bench records in {args.raw} — did the bench run "
                 "export EVO_BENCH_JSON?")

    artifact = {
        "schema": SCHEMA,
        "date": args.date,
        "git": git_short_sha(),
        "provenance": "measured",
        "benches": sorted(benches.values(), key=lambda b: (b["group"], b["name"])),
        "ratios": sorted(ratios.values(), key=lambda r: (r["group"], r["name"])),
    }
    with open(args.out, "w", encoding="utf-8") as f:
        json.dump(artifact, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}: {len(benches)} benches, {len(ratios)} ratios")


if __name__ == "__main__":
    main()
