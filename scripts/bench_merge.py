#!/usr/bin/env python3
"""Merge an EVO_BENCH_JSON raw stream into a BENCH_<date>.json artifact.

The bench harness (rust/src/util/bench.rs) appends one JSONL line per
finished benchmark ({"type":"bench",...}) and per derived ratio
({"type":"ratio",...}). This script folds that stream into the single
committed artifact described in DESIGN.md §14:

    {
      "schema": 1,
      "date": "YYYY-MM-DD",
      "git": "<short sha or null>",
      "provenance": "measured",
      "benches": [{"group","name","median_ns","p10_ns","p90_ns","iters"}],
      "ratios":  [{"group","name","value","target"}]
    }

Duplicate (group, name) pairs keep the LAST occurrence — a re-run in
the same process supersedes earlier samples.

`--sentinel NOTE` writes a "bootstrap-unmeasured" sentinel instead (the
bench suite's shape with null medians, NOTE recorded in the artifact's
`note`), for authoring environments without a Rust toolchain. A
sentinel NEVER overwrites an artifact whose provenance is "measured":
real numbers are strictly more information than a placeholder, and the
bench_compare.py regression gate keys off the measured baseline.
"""

import argparse
import json
import subprocess
import sys

SCHEMA = 1


def load_existing(path):
    """The artifact currently at `path`, or None (absent/unreadable)."""
    try:
        with open(path, encoding="utf-8") as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None


def null_shape(rows, fields):
    """The rows with every measurement field nulled (sentinel shape)."""
    out = []
    for row in rows:
        nulled = {"group": row["group"], "name": row["name"]}
        nulled.update({k: None for k in fields})
        out.append(nulled)
    return out


def git_short_sha():
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10, check=True,
        )
        return out.stdout.strip() or None
    except Exception:
        return None


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--raw", help="EVO_BENCH_JSON stream (JSONL)")
    ap.add_argument("--date", required=True, help="artifact date (YYYY-MM-DD)")
    ap.add_argument("--out", required=True, help="merged artifact path")
    ap.add_argument(
        "--sentinel", metavar="NOTE",
        help="write a bootstrap-unmeasured sentinel (suite shape, null medians) "
             "with NOTE in the artifact's `note` instead of merging measurements; "
             "refuses to overwrite an artifact whose provenance is 'measured'")
    args = ap.parse_args()

    if args.sentinel is not None:
        existing = load_existing(args.out)
        if existing is not None and existing.get("provenance") == "measured":
            sys.exit(
                f"error: {args.out} holds a 'measured' artifact — refusing to "
                "overwrite real medians with a sentinel (drop --sentinel, or "
                "pick a new --out)")
        if existing is None:
            sys.exit(
                f"error: no existing artifact at {args.out} to take the bench "
                "suite's shape from — a sentinel only refreshes a prior one")
        artifact = {
            "schema": SCHEMA,
            "date": args.date,
            "git": git_short_sha(),
            "provenance": "bootstrap-unmeasured",
            "note": args.sentinel,
            "benches": null_shape(
                existing.get("benches", []),
                ["median_ns", "p10_ns", "p90_ns", "iters"]),
            "ratios": null_shape(existing.get("ratios", []), ["value", "target"]),
        }
        with open(args.out, "w", encoding="utf-8") as f:
            json.dump(artifact, f, indent=2)
            f.write("\n")
        print(f"wrote sentinel {args.out}: "
              f"{len(artifact['benches'])} benches (unmeasured)")
        return

    if not args.raw:
        sys.exit("error: --raw is required unless --sentinel is given")

    benches, ratios = {}, {}
    with open(args.raw, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                print(f"warning: {args.raw}:{lineno}: unparseable line skipped ({e})",
                      file=sys.stderr)
                continue
            key = (rec.get("group"), rec.get("name"))
            if None in key:
                print(f"warning: {args.raw}:{lineno}: missing group/name, skipped",
                      file=sys.stderr)
                continue
            if rec.get("type") == "bench":
                benches[key] = {
                    "group": rec["group"], "name": rec["name"],
                    "median_ns": rec["median_ns"],
                    "p10_ns": rec["p10_ns"], "p90_ns": rec["p90_ns"],
                    "iters": rec["iters"],
                }
            elif rec.get("type") == "ratio":
                ratios[key] = {
                    "group": rec["group"], "name": rec["name"],
                    "value": rec["value"], "target": rec["target"],
                }

    if not benches:
        sys.exit(f"error: no bench records in {args.raw} — did the bench run "
                 "export EVO_BENCH_JSON?")

    artifact = {
        "schema": SCHEMA,
        "date": args.date,
        "git": git_short_sha(),
        "provenance": "measured",
        "benches": sorted(benches.values(), key=lambda b: (b["group"], b["name"])),
        "ratios": sorted(ratios.values(), key=lambda r: (r["group"], r["name"])),
    }
    with open(args.out, "w", encoding="utf-8") as f:
        json.dump(artifact, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}: {len(benches)} benches, {len(ratios)} ratios")


if __name__ == "__main__":
    main()
