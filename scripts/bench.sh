#!/usr/bin/env bash
# Hot-path bench run -> committed trajectory artifact (DESIGN.md §14).
#
# Runs the two hot-path bench targets with EVO_BENCH_JSON capture and
# merges the JSONL stream into BENCH_<date>.json at the repo root —
# the artifact a bench-trajectory commit checks in, and the baseline
# scripts/bench_compare.py measures regressions against.
#
# Usage: scripts/bench.sh [--check]
#   --check   after emitting the artifact, compare it against the
#             latest committed BENCH_*.json (>20% median regression or
#             a ratio below target fails).
#
# Env:
#   BENCH_DATE   override the artifact date (YYYY-MM-DD, default: UTC
#                today) — CI uses this to pin names across job steps.
#   BENCH_OUT    override the artifact path entirely.
set -euo pipefail
cd "$(dirname "$0")/.."

DATE="${BENCH_DATE:-$(date -u +%F)}"
OUT="${BENCH_OUT:-BENCH_${DATE}.json}"
RAW="$(mktemp -t evo_bench_raw.XXXXXX.jsonl)"
trap 'rm -f "$RAW"' EXIT

echo "== bench: store + hot_paths (raw stream: $RAW)"
EVO_BENCH_JSON="$RAW" cargo bench --bench store --bench hot_paths

echo "== merge: $OUT"
python3 scripts/bench_merge.py --raw "$RAW" --date "$DATE" --out "$OUT"

if [[ "${1:-}" == "--check" ]]; then
  echo "== compare against the latest committed baseline"
  python3 scripts/bench_compare.py --current "$OUT"
fi
