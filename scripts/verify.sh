#!/usr/bin/env bash
# Tier-1 verification gate (ROADMAP.md), plus rustdoc-as-lint so that
# broken intra-doc links and drifted doc references (the DESIGN.md
# kind of rot) fail fast.
#
# Usage: scripts/verify.sh
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1: cargo build --release"
cargo build --release

echo "== tier-1: cargo test -q"
cargo test -q

echo "== docs: cargo doc --no-deps (warnings are errors)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "== benches: cargo bench --no-run (must always compile)"
cargo bench --no-run

echo "== feature matrix: the optional http-provider backend must never rot"
cargo build --release -p evoengineer --no-default-features
cargo build --release -p evoengineer --features http-provider

echo "verify OK"
